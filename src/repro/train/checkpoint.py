"""Sharded, atomic, mesh-agnostic checkpointing (no orbax available — built
from scratch).

Layout: <dir>/step_<N>/arrays.npz + manifest.json. Writes go to a tmp dir
that is os.replace()'d into place, so a crash mid-save can never corrupt
the latest checkpoint (fault tolerance invariant #1). Arrays are stored as
host numpy keyed by their tree path, which makes checkpoints MESH-AGNOSTIC:
restore() device_puts onto whatever shardings the (possibly different-sized,
i.e. elastic) target mesh provides. Async saves run on a daemon thread.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import numpy as np
import jax

__all__ = ["save", "restore", "latest_step", "Checkpointer"]

_SEP = "/"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind == "V":      # ml_dtypes (bf16 etc.): widen for npz
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _unflatten(tree_like, flat: dict):
    def fetch(path, leaf):
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        arr = flat[key]
        return arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr
    return jax.tree_util.tree_map_with_path(fetch, tree_like)


def save(ckpt_dir: str, step: int, state: Any, meta: Optional[dict] = None,
         keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    np.savez(os.path.join(tmp, "arrays.npz"), **_flatten(state))
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "complete": True, **(meta or {})}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                      # atomic publish
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(_all_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def _all_steps(ckpt_dir: str):
    out = []
    if not os.path.isdir(ckpt_dir):
        return out
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            man = os.path.join(ckpt_dir, name, "manifest.json")
            try:
                with open(man) as f:
                    if json.load(f).get("complete"):
                        out.append(int(name[5:]))
            except (OSError, ValueError):
                continue                        # torn checkpoint: ignored
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _all_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, state_like: Any,
            shardings: Any = None) -> Any:
    """Load a checkpoint onto ``shardings`` (any mesh — elastic restore)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    state = _unflatten(state_like, flat)
    if shardings is not None:
        state = jax.tree.map(jax.device_put, state, shardings)
    return state


class Checkpointer:
    """Async wrapper: save() returns immediately; wait() joins the writer."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir, self.keep = ckpt_dir, keep
        self._thread: Optional[threading.Thread] = None

    def save_async(self, step: int, state: Any, meta: Optional[dict] = None):
        state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        self.wait()
        self._thread = threading.Thread(
            target=save, args=(self.dir, step, state, meta, self.keep),
            daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
