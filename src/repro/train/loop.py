"""Restart-safe training loop with straggler watchdog and failure recovery.

At 1000+ node scale the failure model is: (a) hosts die mid-step, (b) steps
straggle (slow HBM, thermal throttle, network), (c) preemption. The loop
implements the corresponding mitigations at the framework level:

  (a) per-step exception recovery: restore from the last complete
      checkpoint and continue (the synthetic pipeline is a pure function of
      the step index, so the data stream replays exactly);
  (b) an EMA watchdog flags steps slower than ``straggler_factor`` x EMA and
      invokes ``on_straggler`` (at scale: evict/re-shard; here: counted and
      logged — the policy hook is the deliverable);
  (c) atomic checkpoints every ``ckpt_every`` steps + resume-from-latest.

Elasticity: ``elastic_rescale`` re-lowers the step for a new mesh and
re-device_puts the (mesh-agnostic) checkpoint onto it.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax

from . import checkpoint as ckpt

__all__ = ["LoopConfig", "train_loop", "StepStats"]


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    straggler_factor: float = 3.0
    ema_decay: float = 0.9
    max_restores: int = 3


@dataclasses.dataclass
class StepStats:
    steps_run: int = 0
    restores: int = 0
    stragglers: int = 0
    last_loss: float = float("nan")


def train_loop(step_fn: Callable, state: dict, data_iter, lc: LoopConfig,
               fail_injector: Optional[Callable[[int], None]] = None,
               on_straggler: Optional[Callable[[int, float], None]] = None,
               log_every: int = 10) -> StepStats:
    """state = {'params':..., 'opt':...}; step_fn(params, opt, batch) ->
    (params, opt, metrics). Returns aggregate stats (used by tests)."""
    stats = StepStats()
    start = 0
    latest = ckpt.latest_step(lc.ckpt_dir)
    if latest is not None:
        state = ckpt.restore(lc.ckpt_dir, latest, state)
        start = latest + 1
    data_iter.step = start

    ema = None
    step = start
    while step < lc.total_steps:
        batch = next(data_iter)
        t0 = time.perf_counter()
        try:
            if fail_injector is not None:
                fail_injector(step)
            params, opt, metrics = step_fn(state["params"], state["opt"],
                                           batch)
            metrics = jax.device_get(metrics)
            state = {"params": params, "opt": opt}
        except Exception:  # noqa: BLE001 — node failure simulation
            stats.restores += 1
            if stats.restores > lc.max_restores:
                raise
            latest = ckpt.latest_step(lc.ckpt_dir)
            if latest is not None:
                state = ckpt.restore(lc.ckpt_dir, latest, state)
                step = latest + 1
            else:
                step = 0
            data_iter.step = step
            continue
        dt = time.perf_counter() - t0
        if ema is not None and dt > lc.straggler_factor * ema:
            stats.stragglers += 1
            if on_straggler is not None:
                on_straggler(step, dt / ema)
        ema = dt if ema is None else lc.ema_decay * ema + (1 - lc.ema_decay) * dt
        stats.last_loss = float(metrics["loss"])
        stats.steps_run += 1
        if (step + 1) % lc.ckpt_every == 0 or step + 1 == lc.total_steps:
            ckpt.save(lc.ckpt_dir, step, state, keep=lc.keep)
        step += 1
    return stats


def elastic_rescale(state: dict, new_mesh, sharding_fn):
    """Re-place a (host-side) training state onto a different mesh.

    sharding_fn(mesh, state) -> tree of NamedSharding. Works because
    checkpoints/state are mesh-agnostic host arrays (checkpoint.py).
    """
    shardings = sharding_fn(new_mesh, state)
    host = jax.tree.map(lambda x: jax.device_get(x), state)
    return jax.tree.map(jax.device_put, host, shardings)
