from .step import (make_train_step, make_accum_train_step,
                   make_prefill_step, make_decode_step)   # noqa: F401
from .loop import LoopConfig, train_loop                   # noqa: F401
from . import checkpoint                                   # noqa: F401
