"""Train / prefill / decode step builders (mesh-agnostic; the launch layer
applies in/out shardings via jax.jit)."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ..models.model import ModelBundle
from ..optim.adamw import AdamW

__all__ = ["make_train_step", "make_accum_train_step", "make_prefill_step",
           "make_decode_step"]


def make_train_step(bundle: ModelBundle, opt: AdamW):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(bundle.loss)(params, batch)
        params, opt_state, metrics = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, **metrics}
    return train_step


def make_accum_train_step(bundle: ModelBundle, opt: AdamW, accum: int):
    """Gradient accumulation over ``accum`` microbatches (leading dim).

    The grads stay as unreduced partial sums through the scan and the DP
    mean happens once at the end — GSPMD therefore schedules one bucketed
    all-reduce that overlaps the next microbatch's backward (compute/comm
    overlap without manual double buffering).
    """
    def train_step(params, opt_state, batch):
        def micro(carry, mb):
            gsum, lsum = carry
            loss, grads = jax.value_and_grad(bundle.loss)(params, mb)
            gsum = jax.tree.map(jnp.add, gsum,
                                jax.tree.map(lambda g: g.astype(jnp.float32),
                                             grads))
            return (gsum, lsum + loss), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum), _ = jax.lax.scan(micro, (g0, jnp.float32(0)), batch)
        grads = jax.tree.map(lambda g: g / accum, gsum)
        params, opt_state, metrics = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": lsum / accum, **metrics}
    return train_step


def make_prefill_step(bundle: ModelBundle):
    def prefill_step(params, batch):
        return bundle.prefill(params, batch)
    return prefill_step


def make_decode_step(bundle: ModelBundle):
    def decode_step(params, tokens, cache):
        logits, cache = bundle.decode(params, tokens, cache)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], logits, cache
    return decode_step
