"""Compiled-decoder / plan cache shared by the serve, stream, and
pipeline layers.

Every layer that decodes frames ends up building the same two artifacts:
an (unjitted) ``decode_frames`` closure dispatching one backend
configuration, and a jitted wrapper specialized to a fixed frame count
(a stream chunk window, or a serve bucket's batch). Before this cache,
each ``StreamDecoder`` / ``make_decoder`` call built fresh closures — and
because JAX's jit cache is keyed by function *identity*, every new
closure meant a full re-trace and re-compile of an identical program.
Under tenant churn (sessions opening and closing all day) that is a
compile per session.

``PlanCache`` is the process-global registry fixing that. Entries are
keyed by the semantic identity of the compiled program::

    (trellis, spec, DecodePlan, nframes)

materialized here as ``(kind, cfg, nframes, mesh)`` — a ``DecoderConfig``
*is* (trellis, spec, plan knobs), its trellis hashes by canonical
identity (``make_trellis`` is lru_cached), and the kernel-knob subset of
the key is exactly ``kernels.autotune.DecodePlan.cache_key()``. Three
entry kinds:

  * ``frames``  — the backend-dispatch closure (pipeline layer);
  * ``window``  — jitted chunk-window -> bits (stream layer);
  * ``batch``   — jitted (nframes, L, beta) -> (nframes, f) bits
                  (serve layer: one bucket launch).

``stats()`` reports hits / misses and — the number that matters for the
serve acceptance criterion — ``traces``: how many times XLA actually
traced a cached program. One trace per distinct (trellis, spec, plan,
nframes) bucket, no matter how many sessions come and go.
"""
from __future__ import annotations

import threading
import time

import jax
import jax.numpy as jnp

from ..core.pipeline import DecoderConfig, _build_frame_decoder
from ..obs.tracer import get_tracer

__all__ = ["PlanCache", "PLAN_CACHE", "build_window_fn"]


def build_window_fn(spec, decode_frames, nframes: int, trace_hook=None):
    """Jitted window -> bits for a chunk of ``nframes`` frames: frame the
    (v1 + nframes*f + v2, beta) window in-graph, decode, flatten.
    ``trace_hook`` (if given) runs at trace time only — the cache uses it
    to count real compilations."""
    L, f = spec.frame_len, spec.f

    @jax.jit
    def run(window):
        if trace_hook is not None:
            trace_hook()
        starts = jnp.arange(nframes) * f
        idx = starts[:, None] + jnp.arange(L)[None, :]
        frames = window[idx]                    # (nframes, L, beta)
        return decode_frames(frames).reshape(-1)

    return run


class PlanCache:
    """Thread-safe registry of compiled decode programs.

    The default instance is the module-global ``PLAN_CACHE``; tests and
    servers that want isolated accounting pass their own.
    """

    def __init__(self):
        self._lock = threading.RLock()
        self._fns: dict = {}
        self.hits = 0
        self.misses = 0
        self.traces = 0
        self.build_ms = 0.0

    # -- bookkeeping ------------------------------------------------------
    def _get(self, key, build, refresh: bool = False):
        """Cached build. ``refresh=True`` drops any existing entry first —
        the fault-injection harness uses it to force the cold path (an
        evicted / never-compiled plan) on a live server. Misses time the
        build under a ``plan_build`` span; hits/misses bump the tracer's
        counters so a trace file alone tells the cache story."""
        trace = get_tracer()
        with self._lock:
            if refresh:
                self._fns.pop(key, None)
            fn = self._fns.get(key)
            if fn is not None:
                self.hits += 1
                trace.count("plan_cache_hits")
                return fn
            self.misses += 1
            trace.count("plan_cache_misses")
        t0 = time.perf_counter()
        with trace.span("plan_build", kind=str(key[0])):
            fn = build()                        # build outside the lock
        dt_ms = (time.perf_counter() - t0) * 1e3
        with self._lock:
            self.build_ms += dt_ms
            return self._fns.setdefault(key, fn)

    def _mark_trace(self):
        with self._lock:
            self.traces += 1
        get_tracer().count("plan_cache_traces")

    def stats(self) -> dict:
        with self._lock:
            return {"entries": len(self._fns), "hits": self.hits,
                    "misses": self.misses, "traces": self.traces,
                    "build_ms": round(self.build_ms, 3)}

    def clear(self):
        with self._lock:
            self._fns.clear()
            self.hits = self.misses = self.traces = 0
            self.build_ms = 0.0

    # -- entries ----------------------------------------------------------
    def frame_decoder(self, cfg: DecoderConfig, mesh=None):
        """The backend-dispatch ``decode_frames`` closure for ``cfg`` —
        ONE closure per (cfg, mesh), so every jit built on top of it
        shares downstream compilation cache lines. With ``mesh``, the
        frame axis is sharded across the mesh devices
        (distributed/stream.py)."""
        if mesh is None:
            return self._get(("frames", cfg), lambda: _build_frame_decoder(cfg))

        def build():
            from ..distributed.stream import make_sharded_frame_decoder
            return make_sharded_frame_decoder(cfg, mesh)

        return self._get(("frames", cfg, mesh), build)

    def window_decoder(self, cfg: DecoderConfig, nframes: int, *, mesh=None):
        """Jitted chunk-window decoder (stream layer). Callers with a
        custom decode_frames closure must memoize their own
        ``build_window_fn`` result — an anonymous closure has no stable
        identity to key a shared registry on."""
        key = ("window", cfg, int(nframes), mesh)
        return self._get(key, lambda: build_window_fn(
            cfg.spec, self.frame_decoder(cfg, mesh), int(nframes),
            self._mark_trace))

    def batch_decoder(self, cfg: DecoderConfig, nframes: int, *, mesh=None,
                      refresh: bool = False):
        """Jitted (nframes, L, beta) frames -> (nframes, f) bits — the
        serve layer's one-launch-per-bucket entry point. ``nframes`` is
        the bucket's fixed batch (slots x chunk_frames), so each bucket
        compiles exactly once. ``refresh`` forces a rebuild (fault
        injection only — exercises the cold-cache path)."""
        key = ("batch", cfg, int(nframes), mesh)

        def build():
            decode_frames = self.frame_decoder(cfg, mesh)
            mark = self._mark_trace

            @jax.jit
            def run(frames):
                mark()
                return decode_frames(frames)

            return run

        return self._get(key, build, refresh=refresh)


#: Process-global cache: tenant churn anywhere in the process never
#: re-compiles a plan it has seen before.
PLAN_CACHE = PlanCache()
