"""Multi-tenant Viterbi decode service (continuous batching for receivers).

``DecodeServer`` aggregates many independent, heterogeneous LLR streams
into the large frame batches where the Pallas kernels' throughput lives;
``plan_cache.PLAN_CACHE`` is the process-global compiled-plan cache shared
with the stream and pipeline layers. (The LM-serving demo in
``repro.launch.serve`` / ``examples/serve_lm.py`` is unrelated scaffolding
for the transformer side of this repo — THIS package is the Viterbi
service.)
"""
from .plan_cache import PLAN_CACHE, PlanCache          # noqa: F401
from .metrics import BucketMetrics, ServeMetrics, FAULT_COUNTERS  # noqa: F401
from .scheduler import Breaker, Bucket, Session, bucket_plan    # noqa: F401
from .server import (Backpressure, DecodeServer, Draining,  # noqa: F401
                     LaunchTimeout, PoisonedInput, ServeError, ServerFull,
                     SessionQuarantined)
from .checkpoint import (CheckpointError, load_checkpoint,  # noqa: F401
                         save_checkpoint)

__all__ = ["DecodeServer", "ServeError", "ServerFull", "Backpressure",
           "PoisonedInput", "SessionQuarantined", "LaunchTimeout",
           "Draining", "CheckpointError", "save_checkpoint",
           "load_checkpoint", "PlanCache", "PLAN_CACHE", "ServeMetrics",
           "BucketMetrics", "FAULT_COUNTERS", "Breaker", "Bucket",
           "Session", "bucket_plan"]
