"""Multi-tenant Viterbi decode service (continuous batching for receivers).

``DecodeServer`` aggregates many independent, heterogeneous LLR streams
into the large frame batches where the Pallas kernels' throughput lives;
``plan_cache.PLAN_CACHE`` is the process-global compiled-plan cache shared
with the stream and pipeline layers. (The LM-serving demo in
``repro.launch.serve`` / ``examples/serve_lm.py`` is unrelated scaffolding
for the transformer side of this repo — THIS package is the Viterbi
service.)
"""
from .plan_cache import PLAN_CACHE, PlanCache          # noqa: F401
from .metrics import BucketMetrics, ServeMetrics, FAULT_COUNTERS  # noqa: F401
from .scheduler import Bucket, Session, bucket_plan    # noqa: F401
from .server import (Backpressure, DecodeServer, LaunchTimeout,  # noqa: F401
                     PoisonedInput, ServeError, ServerFull,
                     SessionQuarantined)

__all__ = ["DecodeServer", "ServeError", "ServerFull", "Backpressure",
           "PoisonedInput", "SessionQuarantined", "LaunchTimeout",
           "PlanCache", "PLAN_CACHE", "ServeMetrics", "BucketMetrics",
           "FAULT_COUNTERS", "Bucket", "Session", "bucket_plan"]
