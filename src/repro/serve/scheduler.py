"""Session and bucket bookkeeping for the decode service.

A **session** is one tenant: a code configuration plus an unbounded LLR
stream, carried by a ``core.stream.StreamContext`` (rolling v1/v2 overlap
buffer, stream-global depuncture phase). A **bucket** groups live
sessions whose windows can share one batched kernel launch: same trellis,
same frame spec, same compiled plan (``DecodePlan.cache_key()``), same
backend/interpret/mesh. The puncture rate is deliberately NOT part of the
bucket key — depuncturing happens per-session inside the context, so a
rate-1/2 and a rate-3/4 tenant of the same trellis/spec decode in the
same launch.

Scheduling is FIFO over each bucket's window queue (arrival order ==
round-robin when sessions push at similar rates); the server pops up to
``slots`` windows per bucket per step and pads the rest of the fixed
``slots * chunk_frames`` batch with zero frames.

Each ``PendingWindow`` stamps ``t_enq`` at enqueue; the server turns
(take time - t_enq) into the ``queue_wait_ms`` stage histogram
(serve.metrics.STAGES) and the end-to-end window latency at retire — the
queue is where a window's latency story starts.
"""
from __future__ import annotations

import collections
import dataclasses
import time

import numpy as np

from ..core.pipeline import DecoderConfig
from ..core.stream import StreamContext, Window
from ..kernels.autotune import DecodePlan, plan_decode

__all__ = ["PendingWindow", "Session", "Bucket", "Breaker", "bucket_plan"]


class Breaker:
    """Per-bucket circuit breaker over the batched-launch path.

    Classic three-state machine, counted in consecutive launch-attempt
    failures (each retry attempt that raises or times out is one
    failure; any fast-path success resets the streak):

      * ``closed``    — normal; ``threshold`` consecutive failures trip
        it OPEN (the device-failure signal: retries are not clearing the
        fault).
      * ``open``      — the fast path is not attempted at all; the
        server evacuates the bucket's sessions to its failover bucket
        (pinned to the reference backend on a healthy device). After
        ``cooldown`` server steps the breaker goes HALF-OPEN.
      * ``half_open`` — the next batch is used as a probe on the
        original fast path: success closes the breaker (sessions move
        back), failure re-opens it (a fresh trip, a fresh cooldown).

    Every open transition is a *trip*, counted here and in the bucket's
    ``breaker_trips`` fault counter / health.
    """

    def __init__(self, threshold: int = 5, cooldown: int = 4):
        assert threshold > 0 and cooldown > 0
        self.threshold = threshold
        self.cooldown = cooldown
        self.state = "closed"
        self.consecutive = 0          # failures since the last success
        self.trips = 0                # open transitions, cumulative
        self._wait = 0                # steps left in the open cooldown

    def record_failure(self) -> bool:
        """One failed launch attempt; returns True when THIS failure
        trips the breaker open (closed -> open, or a failed half-open
        probe re-opening)."""
        self.consecutive += 1
        if self.state == "half_open" or (
                self.state == "closed"
                and self.consecutive >= self.threshold):
            self.state = "open"
            self._wait = self.cooldown
            self.trips += 1
            return True
        return False

    def record_success(self) -> bool:
        """One successful fast-path launch; returns True when it closes
        a half-open breaker (the probe succeeded — the device is back)."""
        self.consecutive = 0
        if self.state == "half_open":
            self.state = "closed"
            return True
        return False

    def step(self) -> None:
        """One server step elapsed; an open breaker counts down to its
        half-open probe."""
        if self.state == "open":
            self._wait -= 1
            if self._wait <= 0:
                self.state = "half_open"

    def state_dict(self) -> dict:
        return {"state": self.state, "consecutive": self.consecutive,
                "trips": self.trips, "wait": self._wait}

    def load_state(self, state: dict) -> None:
        if state["state"] not in ("closed", "open", "half_open"):
            raise ValueError(f"unknown breaker state {state['state']!r}")
        self.state = state["state"]
        self.consecutive = int(state["consecutive"])
        self.trips = int(state["trips"])
        self._wait = int(state["wait"])

    def snapshot(self) -> dict:
        """JSON-ready row for ``metrics_snapshot()['breakers']``."""
        return {"state": self.state, "trips": self.trips,
                "consecutive": self.consecutive}


def bucket_plan(cfg: DecoderConfig, num_devices: int = 1,
                chunk_frames: int | None = None) -> DecodePlan:
    """The DecodePlan a session of ``cfg`` buckets under — same planning
    call the single-stream front-end uses, so a server session chunks
    exactly like its ``stream_decode`` baseline."""
    pinned = (cfg.frames_per_tile
              if isinstance(cfg.frames_per_tile, int) else None)
    return plan_decode(
        cfg.trellis, cfg.spec, unified=cfg.backend != "kernel_split",
        pack_survivors=cfg.pack_survivors, radix=cfg.radix,
        bm_dtype=cfg.bm_dtype, layout=cfg.layout, num_devices=num_devices,
        chunk_frames=chunk_frames, frames_per_tile=pinned,
        block_frames=cfg.block_frames, overlap=cfg.overlap)


@dataclasses.dataclass
class PendingWindow:
    """One chunk window queued for a batched launch."""
    session: "Session"
    frames: np.ndarray            # (chunk_frames, L, beta) float32
    n_bits: int                   # real bits (tail windows carry padding)
    t_enq: float                  # perf_counter at enqueue: queue_wait_ms
                                  # stage + end-to-end latency both start here


@dataclasses.dataclass
class Session:
    """One tenant stream and its decoded-output queue.

    ``strikes`` counts pushes that failed input validation (poisoned or
    malformed LLRs); once it reaches the server's ``quarantine_after``
    threshold the session is quarantined: ``quarantined`` holds the
    machine-readable reason, further pushes/polls raise
    ``SessionQuarantined``, and only ``close_session`` (teardown) still
    succeeds — one bad tenant never takes down its bucket."""
    sid: int
    cfg: DecoderConfig
    ctx: StreamContext
    bucket: "Bucket"
    inflight: int = 0             # windows queued, not yet decoded
    ready: list = dataclasses.field(default_factory=list)
    closed: bool = False
    strikes: int = 0              # validation failures so far
    quarantined: str | None = None  # reason, once quarantined
    chunk_frames_arg: int | None = None  # open_session arg, for restore

    def _enqueue(self, w: Window) -> None:
        assert w.nframes == self.bucket.chunk_frames    # one bucket geometry
        self.bucket.queue.append(
            PendingWindow(self, w.frames(self.cfg.spec), w.n_bits,
                          time.perf_counter()))
        self.inflight += 1

    def absorb(self, llr) -> int:
        """Feed raw input through the context; queue every completed
        window on the bucket. Returns windows queued."""
        self.ctx.append(llr)
        windows = self.ctx.take_windows()
        for w in windows:
            self._enqueue(w)
        return len(windows)

    def finish(self) -> int:
        """Queue the zero-padded tail as full-chunk windows (the tail can
        exceed one chunk by up to v2-1 stages of missing right context —
        flush_chunks splits it losslessly). Returns windows queued."""
        windows = self.ctx.flush_chunks()
        for w in windows:
            self._enqueue(w)
        return len(windows)

    def take_ready(self) -> np.ndarray:
        out = (np.concatenate(self.ready) if self.ready
               else np.zeros((0,), np.int32))
        self.ready.clear()
        return out


class Bucket:
    """Live sessions sharing one compiled plan — and one launch per step.

    ``mesh`` is the bucket's device placement (the server's mesh for
    primary buckets; None for a failover bucket — device loss means the
    evacuation target is the host/reference path). ``pinned`` marks a
    failover bucket: its launches are pinned to the reference backend,
    never consult the fault injector (the evacuation target is the path
    that must work when the fast path doesn't — same contract as
    ``_ref_fallback``), and ``primary`` points back at the bucket whose
    breaker evacuation created it (half-open probes re-dispatch on the
    primary's fast path)."""

    def __init__(self, key, cfg: DecoderConfig, plan: DecodePlan, *,
                 mesh=None, pinned: bool = False, primary=None,
                 breaker: Breaker | None = None):
        self.key = key
        self.plan = plan
        self.chunk_frames = plan.chunk_frames
        # the decode identity strips the rate: depuncture is per-session
        # upstream, so every rate shares this bucket's compiled decoders
        self.decode_cfg = dataclasses.replace(cfg, rate="1/2")
        self.sessions: set[int] = set()
        self.queue: collections.deque[PendingWindow] = collections.deque()
        self.inflight: collections.deque = collections.deque()  # launches
        self.mesh = mesh
        self.pinned = pinned
        self.primary: "Bucket | None" = primary
        self.breaker = breaker if breaker is not None else Breaker()
        self.id = (f"K{cfg.trellis.k}-f{cfg.spec.f}-"
                   f"C{self.chunk_frames}-{plan.fingerprint()}"
                   + ("-failover" if pinned else ""))

    def tile_pad(self, batch_frames: int) -> int:
        """Frames of tile padding a launch of ``batch_frames`` pays: the
        kernel wrappers round the frame axis up to the plan's tile
        (ops._pad_frames); the reference backend vmaps exactly. Under a
        block-parallel plan the kernel's frame axis carries BLOCKS
        (batch_frames * block_frames of them), so the rounding happens in
        block units and the result is converted back to outer frames."""
        if self.decode_cfg.backend == "reference":
            return 0
        bf = self.plan.block_frames
        units = batch_frames * bf
        ft = self.plan.frames_per_tile
        return (-(-units // ft) * ft - units) // bf

    def take(self, max_windows: int) -> list[PendingWindow]:
        out = []
        while self.queue and len(out) < max_windows:
            w = self.queue.popleft()
            w.session.inflight -= 1
            out.append(w)
        return out
