"""Per-bucket serving metrics: latency percentiles, batch occupancy,
derived throughput, stage-latency breakdowns, and fault-tolerance health
counters.

The serve layer's whole reason to exist is batch occupancy — the kernels
only hit their throughput at high frame counts per launch — so the
metrics are organized around the launch: how many frames of each batched
launch carried live session data vs padding, and how long each window
waited between enqueue (push) and materialized bits. Latencies land in
fixed-bucket histograms (repro.obs.hist): recording stays O(1) per
window, ``totals()`` aggregates by merging bucket histograms in
O(buckets x bucket-count) instead of re-concatenating every retained
sample, and memory is O(buckets) no matter how long the server lives.
The ``p50_ms``/``p99_ms`` snapshot keys are unchanged (same names, same
rounding) so recorded BENCH_kernels.json serve rows stay comparable;
their values are now bucket-resolution percentiles (~19% geometric
buckets, exact for degenerate distributions).

Each bucket (and the server total) also derives throughput from a
monotonic epoch: ``uptime_s`` since the bucket/server first existed and
``mbps`` = decoded bits / uptime — so front-ends stop hand-computing
aggregate rates around their own loops.

``stage(name)`` returns the server-wide histogram for one pipeline stage
(queue_wait / batch_pack / launch / retire, in ms); the snapshot carries
their summaries as the stage-latency breakdown the tracing layer's spans
drill into.

Since the fault-tolerance layer, each bucket also tracks its failure
story: launch errors and deadline timeouts, retries, launches that
DEGRADED to the reference-decoder fallback, plan-cache refreshes forced
by fault injection, poisoned pushes (and how many values were
sanitized), and sessions quarantined out of the bucket. ``health`` folds
those into a one-word per-bucket status the snapshot carries:
``ok`` (no faults seen), ``impaired`` (faults seen, all recovered by
retry/sanitize), ``degraded`` (at least one launch fell back to the
reference decoder — results stay correct, the bucket is not running its
compiled fast path).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from ..obs.hist import Histogram

__all__ = ["BucketMetrics", "ServeMetrics", "percentile", "FAULT_COUNTERS",
           "STAGES"]

#: Counter fields summed into ``ServeMetrics.totals()`` and carried in
#: every snapshot row (the robustness-observability contract).
#: ``breaker_trips`` counts circuit-breaker open transitions (consecutive
#: launch failures exceeded the threshold — the device-failure signal);
#: ``evacuated`` counts sessions moved off a tripped bucket to its
#: failover bucket (pinned to the reference backend / healthy device).
FAULT_COUNTERS = ("launch_errors", "timeouts", "retries", "degraded",
                  "cache_refreshes", "poisoned_pushes", "sanitized_values",
                  "quarantined", "breaker_trips", "evacuated")

#: Pipeline stages with a server-wide latency histogram (all in ms; the
#: tracing spans of the same names carry the per-occurrence detail).
STAGES = ("queue_wait_ms", "batch_pack_ms", "launch_ms", "retire_ms")


def percentile(samples, p: float) -> float:
    """Exact p-th percentile of raw ``samples`` (0.0 when empty) — kept
    for tests/tools that hold their own sample lists; the serve rows
    themselves are histogram-backed now."""
    if not len(samples):
        return 0.0
    return float(np.percentile(np.asarray(samples, np.float64), p))


@dataclasses.dataclass
class BucketMetrics:
    """Counters for one session bucket (one compiled plan)."""
    bucket: str                       # plan fingerprint / display id
    launches: int = 0
    windows: int = 0                  # live windows decoded
    frames: int = 0                   # live frames decoded
    pad_frames: int = 0               # padding frames launched
    bits: int = 0                     # real bits returned to sessions
    # -- fault-tolerance counters -----------------------------------------
    launch_errors: int = 0            # kernel launches that raised
    timeouts: int = 0                 # launches past the deadline
    retries: int = 0                  # re-dispatch attempts after a fault
    degraded: int = 0                 # launches served by the ref fallback
    cache_refreshes: int = 0          # forced plan-cache rebuilds
    poisoned_pushes: int = 0          # pushes failing input validation
    sanitized_values: int = 0         # LLR values scrubbed/clamped
    quarantined: int = 0              # sessions quarantined (cumulative)
    breaker_trips: int = 0            # circuit-breaker open transitions
    evacuated: int = 0                # sessions evacuated off this bucket
    last_error: str = ""              # most recent fault, human-readable
    latency: Histogram = dataclasses.field(
        default_factory=Histogram.latency_ms)
    t0: float = dataclasses.field(default_factory=time.perf_counter)

    def record_launch(self, live_frames: int, pad_frames: int, windows: int,
                      bits: int, window_latency_ms) -> None:
        self.launches += 1
        self.frames += live_frames
        self.pad_frames += pad_frames
        self.windows += windows
        self.bits += bits
        self.latency.extend(float(t) for t in window_latency_ms)

    def record_fault(self, counter: str, error: str = "", n: int = 1) -> None:
        """Bump one fault counter (a FAULT_COUNTERS name); remember the
        most recent error string for the snapshot. An unknown counter
        name is a real ValueError — this is the fault-accounting contract
        and must not vanish under ``python -O`` the way an assert would."""
        if counter not in FAULT_COUNTERS:
            raise ValueError(
                f"unknown fault counter {counter!r}; expected one of "
                f"{FAULT_COUNTERS}")
        setattr(self, counter, getattr(self, counter) + n)
        if error:
            self.last_error = error

    @property
    def occupancy(self) -> float:
        """Live fraction of launched frames (1.0 = perfectly packed)."""
        total = self.frames + self.pad_frames
        return self.frames / total if total else 0.0

    @property
    def uptime_s(self) -> float:
        """Monotonic seconds since this bucket first saw a session."""
        return time.perf_counter() - self.t0

    @property
    def mbps(self) -> float:
        """Decoded Mb/s over the bucket's lifetime."""
        dt = self.uptime_s
        return self.bits / dt / 1e6 if dt > 0 else 0.0

    @property
    def health(self) -> str:
        """'ok' | 'impaired' (faults seen, all recovered on the fast
        path) | 'degraded' (reference fallback was needed, or the
        bucket's circuit breaker tripped and its sessions were
        evacuated)."""
        if self.degraded or self.breaker_trips:
            return "degraded"
        if (self.launch_errors or self.timeouts or self.retries
                or self.poisoned_pushes or self.quarantined):
            return "impaired"
        return "ok"

    def p50_ms(self) -> float:
        return self.latency.percentile(50)

    def p99_ms(self) -> float:
        return self.latency.percentile(99)

    def snapshot(self) -> dict:
        """JSON-ready row (benchmarks/trajectory 'serve' section shape)."""
        row = {"bucket": self.bucket, "launches": self.launches,
               "windows": self.windows, "frames": self.frames,
               "pad_frames": self.pad_frames, "bits": self.bits,
               "occupancy": round(self.occupancy, 4),
               "p50_ms": round(self.p50_ms(), 3),
               "p99_ms": round(self.p99_ms(), 3),
               "mbps": round(self.mbps, 4),
               "uptime_s": round(self.uptime_s, 3),
               "health": self.health}
        row.update({c: getattr(self, c) for c in FAULT_COUNTERS})
        if self.last_error:
            row["last_error"] = self.last_error
        return row

    #: Plain counter fields round-tripped by the serve checkpoint.
    _STATE_FIELDS = ("launches", "windows", "frames", "pad_frames",
                     "bits") + FAULT_COUNTERS

    def state_dict(self) -> dict:
        """JSON-ready full state for the serve checkpoint — counters,
        the latency histogram, and the uptime accumulated so far (the
        monotonic epoch itself cannot cross processes)."""
        state = {f: getattr(self, f) for f in self._STATE_FIELDS}
        state["last_error"] = self.last_error
        state["uptime_s"] = self.uptime_s
        state["latency"] = self.latency.state_dict()
        return state

    def load_state(self, state: dict) -> None:
        """Restore a ``state_dict``; uptime continues from the saved
        value (a restored server reports cumulative uptime, not a fresh
        epoch — the crash-recovery CI stage gates this)."""
        for f in self._STATE_FIELDS:
            setattr(self, f, int(state[f]))
        self.last_error = str(state["last_error"])
        self.t0 = time.perf_counter() - float(state["uptime_s"])
        self.latency.load_state(state["latency"])


class ServeMetrics:
    """All buckets of one DecodeServer, plus the server-wide stage
    histograms and the throughput epoch."""

    def __init__(self):
        self._buckets: dict[str, BucketMetrics] = {}
        self._stages: dict[str, Histogram] = {}
        self.t0 = time.perf_counter()

    def bucket(self, bucket_id: str) -> BucketMetrics:
        m = self._buckets.get(bucket_id)
        if m is None:
            m = self._buckets[bucket_id] = BucketMetrics(bucket_id)
        return m

    def stage(self, name: str) -> Histogram:
        """The server-wide latency histogram for one pipeline stage."""
        h = self._stages.get(name)
        if h is None:
            h = self._stages[name] = Histogram.latency_ms()
        return h

    def stage_snapshot(self) -> dict:
        """{stage: summary} — the stage-latency breakdown rows."""
        return {name: h.snapshot() for name, h in self._stages.items()}

    def stage_histograms(self) -> dict:
        """{stage: {buckets, sum, count}} — the FULL stage histograms in
        Prometheus histogram shape: ``buckets`` is ``[le, cumulative]``
        pairs including the terminal ``+Inf`` bucket (a string, so the
        snapshot stays strict JSON). ``stage_snapshot`` carries the
        summary stats; this carries the distribution a scrape can
        aggregate across servers (export.prometheus_text emits it as
        ``_bucket``/``_sum``/``_count`` sample lines)."""
        def shape(h):
            return {"buckets": [["+Inf" if le == float("inf") else le, c]
                                for le, c in h.cumulative()],
                    "sum": round(h.total, 6), "count": h.count}
        return {name: shape(h) for name, h in self._stages.items()}

    def state_dict(self) -> dict:
        """Everything the serve checkpoint persists about metrics: every
        bucket's counters/latency, the stage histograms, and the
        server-wide uptime."""
        return {"uptime_s": time.perf_counter() - self.t0,
                "buckets": {bid: m.state_dict()
                            for bid, m in self._buckets.items()},
                "stages": {name: h.state_dict()
                           for name, h in self._stages.items()}}

    def load_state(self, state: dict) -> None:
        """Restore a ``state_dict`` — fault counters and uptime carry
        across the restore, so ``metrics_snapshot()`` tells one
        continuous story over the crash boundary."""
        self.t0 = time.perf_counter() - float(state["uptime_s"])
        for bid, mstate in state["buckets"].items():
            self.bucket(bid).load_state(mstate)
        for name, hstate in state["stages"].items():
            self.stage(name).load_state(hstate)

    def __iter__(self):
        return iter(self._buckets.values())

    def snapshot(self) -> list[dict]:
        return [m.snapshot() for m in self._buckets.values()]

    def totals(self) -> dict:
        lat = Histogram.latency_ms()
        for m in self:
            lat.merge(m.latency)
        frames = sum(m.frames for m in self)
        pad = sum(m.pad_frames for m in self)
        bits = sum(m.bits for m in self)
        uptime = time.perf_counter() - self.t0
        out = {"launches": sum(m.launches for m in self),
               "windows": sum(m.windows for m in self),
               "frames": frames, "pad_frames": pad, "bits": bits,
               "occupancy": frames / (frames + pad) if frames + pad else 0.0,
               "p50_ms": lat.percentile(50), "p99_ms": lat.percentile(99),
               "uptime_s": round(uptime, 3),
               "mbps": round(bits / uptime / 1e6 if uptime > 0 else 0.0, 4)}
        out.update({c: sum(getattr(m, c) for m in self)
                    for c in FAULT_COUNTERS})
        healths = [m.health for m in self]
        out["health"] = ("degraded" if "degraded" in healths else
                         "impaired" if "impaired" in healths else "ok")
        return out
