"""Per-bucket serving metrics: latency percentiles and batch occupancy.

The serve layer's whole reason to exist is batch occupancy — the kernels
only hit their throughput at high frame counts per launch — so the
metrics are organized around the launch: how many frames of each batched
launch carried live session data vs padding, and how long each window
waited between enqueue (push) and materialized bits. Latencies are plain
host wall-clock samples; percentiles are computed on demand so recording
stays O(1) per window.
"""
from __future__ import annotations

import collections
import dataclasses

import numpy as np

__all__ = ["BucketMetrics", "ServeMetrics", "percentile", "LATENCY_SAMPLES"]

#: Latency samples retained per bucket (rolling window — a long-running
#: server keeps O(1) memory; percentiles describe recent traffic).
LATENCY_SAMPLES = 4096


def percentile(samples, p: float) -> float:
    """p-th percentile of ``samples`` (0.0 when empty)."""
    if not len(samples):
        return 0.0
    return float(np.percentile(np.asarray(samples, np.float64), p))


@dataclasses.dataclass
class BucketMetrics:
    """Counters for one session bucket (one compiled plan)."""
    bucket: str                       # plan fingerprint / display id
    launches: int = 0
    windows: int = 0                  # live windows decoded
    frames: int = 0                   # live frames decoded
    pad_frames: int = 0               # padding frames launched
    bits: int = 0                     # real bits returned to sessions
    latency_ms: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=LATENCY_SAMPLES))

    def record_launch(self, live_frames: int, pad_frames: int, windows: int,
                      bits: int, window_latency_ms) -> None:
        self.launches += 1
        self.frames += live_frames
        self.pad_frames += pad_frames
        self.windows += windows
        self.bits += bits
        self.latency_ms.extend(float(t) for t in window_latency_ms)

    @property
    def occupancy(self) -> float:
        """Live fraction of launched frames (1.0 = perfectly packed)."""
        total = self.frames + self.pad_frames
        return self.frames / total if total else 0.0

    def p50_ms(self) -> float:
        return percentile(self.latency_ms, 50)

    def p99_ms(self) -> float:
        return percentile(self.latency_ms, 99)

    def snapshot(self) -> dict:
        """JSON-ready row (benchmarks/trajectory 'serve' section shape)."""
        return {"bucket": self.bucket, "launches": self.launches,
                "windows": self.windows, "frames": self.frames,
                "pad_frames": self.pad_frames, "bits": self.bits,
                "occupancy": round(self.occupancy, 4),
                "p50_ms": round(self.p50_ms(), 3),
                "p99_ms": round(self.p99_ms(), 3)}


class ServeMetrics:
    """All buckets of one DecodeServer."""

    def __init__(self):
        self._buckets: dict[str, BucketMetrics] = {}

    def bucket(self, bucket_id: str) -> BucketMetrics:
        m = self._buckets.get(bucket_id)
        if m is None:
            m = self._buckets[bucket_id] = BucketMetrics(bucket_id)
        return m

    def __iter__(self):
        return iter(self._buckets.values())

    def snapshot(self) -> list[dict]:
        return [m.snapshot() for m in self._buckets.values()]

    def totals(self) -> dict:
        lat = [t for m in self for t in m.latency_ms]
        frames = sum(m.frames for m in self)
        pad = sum(m.pad_frames for m in self)
        return {"launches": sum(m.launches for m in self),
                "windows": sum(m.windows for m in self),
                "frames": frames, "pad_frames": pad,
                "bits": sum(m.bits for m in self),
                "occupancy": frames / (frames + pad) if frames + pad else 0.0,
                "p50_ms": percentile(lat, 50), "p99_ms": percentile(lat, 99)}
