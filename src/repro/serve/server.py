"""DecodeServer: a multi-tenant, slot-based Viterbi decode service.

Continuous-batching for receivers instead of language models: sessions
(each a code config + an unbounded LLR stream) are admitted into the
server, grouped into buckets by (trellis, spec, compiled plan), and each
``step()`` packs up to ``slots`` pending chunk windows per bucket into
ONE batched kernel launch (partial batches are padded to the plan's tile
multiple inside the kernel wrapper — ``chunk_frames`` is already a tile
multiple, so a full-slot launch pads nothing). Per-session bits come
back bit-identical to running that session
alone through ``core.stream.stream_decode``: frames decode independently,
and the per-session chunking/flush geometry is exactly the single-stream
context's.

The compiled-plan cache (plan_cache.PLAN_CACHE by default) guarantees
tenant churn never re-compiles: one trace per (trellis, spec, plan,
batch-nframes) bucket for the lifetime of the process.

Flow control is explicit and synchronous:

  * admission — ``open_session`` raises ``ServerFull`` beyond
    ``max_sessions`` live sessions;
  * backpressure — ``push`` raises ``Backpressure`` once a session has
    ``queue_depth`` windows pending (call ``step()`` to drain, then
    retry);
  * ``step()`` runs one launch per bucket with pending work; ``poll``
    collects a session's decoded bits; ``close_session`` flushes the
    tail, drains, and frees the slot.

All flow-control and per-session failures derive from ``ServeError``,
which carries a machine-readable ``retry_after_steps`` hint (how many
``step()`` calls should clear the condition; None when retrying won't
help). The server loop itself NEVER dies on a bad tenant or a bad
launch; errors surface on that session's ``push``/``poll``.

Fault tolerance (one poisoned buffer or failed launch must not corrupt
a bucket):

  * input hardening — every ``push`` is validated and (by default)
    sanitized: NaN/Inf become neutral zero LLRs, |llr| > ``llr_clip``
    clamps (core.sanitize; bit-identical on clean inputs). A push that
    fails validation is a STRIKE; after ``quarantine_after`` strikes the
    session is quarantined — further ``push``/``poll`` raise
    ``SessionQuarantined`` (structured: sid/reason/strikes) while
    ``close_session`` still tears it down cleanly.
  * launch deadline + retry — a batched launch that raises, or exceeds
    ``launch_timeout_s`` wall-clock, is retried up to ``max_retries``
    times with exponential backoff (``backoff_s * 2**attempt``).
  * graceful degrade — when retries are exhausted the batch is decoded
    by the reference backend (``backend='reference'``, bit-identical to
    the kernels at fp32) instead of the bucket's compiled fast path, so
    healthy sessions still get correct bits; the bucket's ``degraded``
    counter and ``health`` reflect it. A launch whose results fail to
    materialize in ``_retire`` is re-decoded the same way.
  * observability — per-bucket error/retry/timeout/degraded/quarantine
    counters and a health field in ``metrics_snapshot()``.

``faults=`` accepts a ``repro.testing.faults.FaultInjector`` whose
seeded schedule exercises all of the above deterministically (kernel
exceptions, slow launches, poisoned LLRs, plan-cache evictions); it is
None in production and every hook is pay-nothing when unset. The
injected-slow-launch deadline is cooperative: JAX cannot preempt a
dispatched computation, so the deadline is checked around the dispatch
(and observed again at materialize time) rather than interrupting it.

With ``mesh=...`` every bucket's batch is sharded across the mesh's
devices (distributed/stream.py) — the batch is the frame axis, so the
scale-out story of the single stream carries over unchanged.

Durability (PR 8 — the service survives bad *processes* and bad
*devices*, not just bad inputs and bad launches):

  * checkpoint/restore — ``checkpoint(path)`` writes an atomic
    (tmp+rename), CRC-validated, schema-versioned snapshot of the whole
    server: every session's bounded carry state
    (``StreamContext.state_dict()``), undelivered decoded bits, queued
    windows, quarantine strikes, circuit-breaker states, and the full
    fault/metric counters. ``DecodeServer.restore(path)`` rebuilds an
    equivalent server in a fresh process; every restored stream resumes
    BIT-IDENTICALLY (serve/checkpoint.py; corrupt or version-mismatched
    files raise ``CheckpointError`` — never a half-loaded server).
  * drain — ``drain(checkpoint=path)`` stops admitting (``Draining`` on
    ``open_session``/``push``), retires every in-flight launch, and
    snapshots: the operational stop-the-world handoff (drain -> snapshot
    -> restart elsewhere).
  * circuit breakers + failover — ``threshold`` consecutive launch
    failures on a bucket trip its breaker OPEN (the device-failure
    signal): its sessions and queued windows are EVACUATED to a failover
    bucket pinned to the reference backend on the host (``mesh=None`` —
    the healthy device), counted in ``breaker_trips``/``evacuated`` and
    visible in ``metrics_snapshot()['breakers']`` and health. After a
    cooldown the breaker half-opens and the next batch probes the
    original fast path; success closes it and moves the sessions back.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np
import jax.numpy as jnp

from ..core.pipeline import DecoderConfig
from ..core.sanitize import LLR_CLIP, sanitize_llr
from ..core.stream import StreamContext
from ..obs.tracer import get_tracer
from .metrics import ServeMetrics
from .plan_cache import PLAN_CACHE, PlanCache
from .scheduler import Breaker, Bucket, Session, bucket_plan

__all__ = ["DecodeServer", "ServeError", "ServerFull", "Backpressure",
           "PoisonedInput", "SessionQuarantined", "LaunchTimeout",
           "Draining"]


class ServeError(RuntimeError):
    """Base class of every serve-layer error.

    ``retry_after_steps`` is a machine-readable hint: how many ``step()``
    calls the caller should drive before retrying the failed operation
    (None = retrying will not help; fix the condition instead)."""

    def __init__(self, msg: str, *, retry_after_steps: int | None = None):
        super().__init__(msg)
        self.retry_after_steps = retry_after_steps


class ServerFull(ServeError):
    """Admission refused: the server is at max_sessions live sessions."""


class Backpressure(ServeError):
    """Push refused: the session already has queue_depth windows pending.

    The caller should drive ``step()`` (``retry_after_steps`` estimates
    how many) and retry."""


class PoisonedInput(ServeError):
    """Push rejected by input validation (malformed shape, or poisoned
    values under the 'raise' sanitize policy). Counts one strike toward
    quarantine; the push absorbed nothing, so a corrected retry is safe."""

    def __init__(self, msg: str, *, sid: int, n_bad: int = 0):
        super().__init__(msg, retry_after_steps=None)
        self.sid = sid
        self.n_bad = n_bad


class SessionQuarantined(ServeError):
    """The session exceeded the validation-failure threshold and is
    quarantined: pushes and polls are refused (structured sid/reason/
    strikes); ``close_session`` still works and returns any bits decoded
    before quarantine."""

    def __init__(self, sid: int, reason: str, strikes: int):
        super().__init__(
            f"session {sid} is quarantined after {strikes} input-validation "
            f"failures (last: {reason}); close_session() to tear it down",
            retry_after_steps=None)
        self.sid = sid
        self.reason = reason
        self.strikes = strikes


class LaunchTimeout(ServeError):
    """A batched launch exceeded the per-launch deadline (internal retry
    signal; surfaces only in bucket metrics/last_error)."""


class Draining(ServeError):
    """The server is draining toward a snapshot/handoff: admission and
    pushes are refused (``retry_after_steps`` is None — retry against
    the RESTORED server, not this one); ``step``/``poll``/
    ``close_session`` keep working so in-flight work retires cleanly."""

    def __init__(self, what: str):
        super().__init__(
            f"server is draining; {what} refused — finish the snapshot "
            f"and retry against the restored server",
            retry_after_steps=None)


class DecodeServer:
    """Slot-based batching decode service over heterogeneous sessions.

    slots:        max windows batched per bucket per step. A steady-state
                  full bucket launches ``slots * chunk_frames`` frames in
                  one fixed shape — one compile per bucket, regardless of
                  session churn (drain tails add at most one shape per
                  distinct partial batch size, each compiled once).
    max_sessions: admission limit over all buckets.
    queue_depth:  per-session pending-window limit before Backpressure.
    depth:        batched launches allowed in flight per bucket behind
                  the dispatch front (1 = double buffering, as in
                  StreamDecoder; 0 = synchronous, for debugging).
    mesh:         optional 1-D 'frames' mesh — bucket batches are then
                  sharded across its devices.
    cache:        PlanCache override (default: process-global PLAN_CACHE).
    launch_timeout_s: per-launch wall-clock deadline (None = no deadline).
    max_retries:  re-dispatch attempts after a failed/timed-out launch
                  before degrading to the reference fallback.
    backoff_s:    base retry backoff; attempt i sleeps backoff_s * 2**i.
    sanitize:     push input policy — 'zero' (scrub NaN/Inf, clamp
                  out-of-range; default), 'raise' (reject poisoned
                  pushes), 'off' (trust the tenant).
    llr_clip:     out-of-range magnitude threshold for sanitization.
    quarantine_after: validation-failure strikes before a session is
                  quarantined.
    faults:       optional repro.testing.faults.FaultInjector (tests/CI
                  chaos only; None in production).
    trace:        optional repro.obs.Tracer recording push/launch/retry/
                  retire spans and stage latencies. None (default)
                  resolves to the process-global tracer — a pay-nothing
                  no-op unless ``repro.obs.set_tracer`` enabled one.
    """

    def __init__(self, *, slots: int = 4, max_sessions: int = 64,
                 queue_depth: int = 8, depth: int = 1, mesh=None,
                 cache: PlanCache | None = None,
                 launch_timeout_s: float | None = None,
                 max_retries: int = 2, backoff_s: float = 0.01,
                 sanitize: str = "zero", llr_clip: float = LLR_CLIP,
                 quarantine_after: int = 3,
                 breaker_threshold: int = 5, breaker_cooldown: int = 4,
                 faults=None, trace=None):
        assert slots > 0 and max_sessions > 0 and queue_depth > 0
        assert depth >= 0
        assert max_retries >= 0 and backoff_s >= 0.0
        assert quarantine_after > 0
        assert breaker_threshold > 0 and breaker_cooldown > 0
        assert sanitize in ("zero", "raise", "off")
        self.slots = slots
        self.max_sessions = max_sessions
        self.queue_depth = queue_depth
        self.depth = depth                    # launches left in flight
        self.mesh = mesh
        self.cache = cache if cache is not None else PLAN_CACHE
        self.launch_timeout_s = launch_timeout_s
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.sanitize = sanitize
        self.llr_clip = llr_clip
        self.quarantine_after = quarantine_after
        self.breaker_threshold = breaker_threshold
        self.breaker_cooldown = breaker_cooldown
        self.faults = faults
        self.trace = trace if trace is not None else get_tracer()
        self.metrics = ServeMetrics()
        self._sessions: dict[int, Session] = {}
        self._buckets: dict[tuple, Bucket] = {}
        self._next_sid = 0
        self._draining = False
        self.checkpoint_saves = 0
        self.checkpoint_restores = 0

    def init_kwargs(self) -> dict:
        """The JSON-serializable constructor knobs — what the checkpoint
        persists so ``restore`` rebuilds an equivalently configured
        server (mesh/cache/faults/trace are process-local and passed
        fresh at restore time)."""
        return {"slots": self.slots, "max_sessions": self.max_sessions,
                "queue_depth": self.queue_depth, "depth": self.depth,
                "launch_timeout_s": self.launch_timeout_s,
                "max_retries": self.max_retries,
                "backoff_s": self.backoff_s, "sanitize": self.sanitize,
                "llr_clip": float(self.llr_clip),
                "quarantine_after": self.quarantine_after,
                "breaker_threshold": self.breaker_threshold,
                "breaker_cooldown": self.breaker_cooldown}

    # -- admission --------------------------------------------------------
    @property
    def num_sessions(self) -> int:
        return len(self._sessions)

    def open_session(self, cfg: DecoderConfig,
                     chunk_frames: int | None = None, *,
                     low_latency: bool = False) -> int:
        """Admit one tenant; returns its session id. Sessions of the same
        (trellis, spec, plan) — any puncture rate — share a bucket. A
        bucket whose circuit breaker is not closed admits new sessions
        straight onto its failover bucket (no tenant is placed on a
        known-bad device); a draining server refuses admission.

        ``low_latency=True`` is the latency-SLO option: it sets
        ``block_frames='auto'`` on the session's config (unless the
        tenant already chose a block decomposition), so long frames are
        decoded as many short intra-frame blocks — each kernel launch
        scans f/block_frames + 2*overlap stages instead of v1+f+v2,
        shrinking per-window launch latency at the truncated-traceback
        BER cost documented on DecoderConfig. The plan's cache_key
        carries the resolved knobs, so low-latency sessions bucket
        separately from exact ones automatically."""
        if self._draining:
            raise Draining("open_session")
        if len(self._sessions) >= self.max_sessions:
            raise ServerFull(
                f"{len(self._sessions)} live sessions (max_sessions="
                f"{self.max_sessions}); close one or raise the limit")
        if low_latency and cfg.block_frames == 1:
            cfg = dataclasses.replace(cfg, block_frames="auto")
        return self._admit(cfg, chunk_frames)

    def _bucket_for(self, cfg: DecoderConfig,
                    chunk_frames: int | None) -> Bucket:
        ndev = int(self.mesh.devices.size) if self.mesh is not None else 1
        plan = bucket_plan(cfg, num_devices=ndev, chunk_frames=chunk_frames)
        key = (cfg.trellis, cfg.spec, plan.cache_key(), cfg.backend,
               cfg.interpret, self.mesh)
        bucket = self._buckets.get(key)
        if bucket is None:
            bucket = self._buckets[key] = Bucket(
                key, cfg, plan, mesh=self.mesh,
                breaker=Breaker(self.breaker_threshold,
                                self.breaker_cooldown))
        return bucket

    def _failover_bucket(self, primary: Bucket) -> Bucket:
        """The evacuation target for ``primary``: same trellis/spec/plan
        geometry (windows stay launch-compatible), pinned to the
        reference backend on the host (``mesh=None`` — device loss means
        the mesh is the thing we do not trust)."""
        key = primary.key + ("failover",)
        bucket = self._buckets.get(key)
        if bucket is None:
            cfg = dataclasses.replace(primary.decode_cfg,
                                      backend="reference", renorm_every=1)
            bucket = self._buckets[key] = Bucket(
                key, cfg, primary.plan, mesh=None, pinned=True,
                primary=primary)
        return bucket

    def _admit(self, cfg: DecoderConfig, chunk_frames: int | None,
               sid: int | None = None) -> int:
        """Shared admission core for ``open_session`` and checkpoint
        ``restore`` (which replays saved sids)."""
        bucket = self._bucket_for(cfg, chunk_frames)
        if bucket.breaker.state != "closed":
            bucket = self._failover_bucket(bucket)
        if sid is None:
            sid = self._next_sid
            self._next_sid += 1
        # the server sanitizes at ITS push boundary (so strikes/counters
        # land on the session); the context's own scrub is off
        ctx = StreamContext(cfg.spec, cfg.trellis.beta, bucket.chunk_frames,
                            cfg.rate, sanitize="off")
        session = Session(sid, cfg, ctx, bucket)
        session.chunk_frames_arg = chunk_frames
        self._sessions[sid] = session
        bucket.sessions.add(sid)
        return sid

    def _session(self, sid: int) -> Session:
        try:
            return self._sessions[sid]
        except KeyError:
            raise KeyError(f"no live session {sid}") from None

    # -- input hardening --------------------------------------------------
    def _strike(self, session: Session, reason: str) -> None:
        """One validation failure; quarantine at the threshold."""
        bm = self.metrics.bucket(session.bucket.id)
        session.strikes += 1
        bm.record_fault("poisoned_pushes", error=reason)
        if session.quarantined is None \
                and session.strikes >= self.quarantine_after:
            session.quarantined = reason
            bm.record_fault("quarantined")

    def _validate_push(self, session: Session, llr):
        """Convert + validate + sanitize one push; returns the clean
        array. Strikes (and possibly quarantines) on failure."""
        try:
            arr = np.asarray(llr, np.float32)
        except (TypeError, ValueError) as e:
            reason = f"push is not numeric: {e}"
            self._strike(session, reason)
            raise PoisonedInput(f"session {session.sid}: {reason}",
                                sid=session.sid) from None
        try:
            session.ctx.check_shape(arr)
            if self.sanitize != "off":
                arr, n_bad = sanitize_llr(arr, self.llr_clip, self.sanitize)
            else:
                n_bad = 0
        except ValueError as e:
            self._strike(session, str(e))
            raise PoisonedInput(f"session {session.sid}: {e}",
                                sid=session.sid) from None
        if n_bad:
            # sanitized to safety — still a strike (a tenant repeatedly
            # sending poison gets quarantined even under 'zero' policy)
            bm = self.metrics.bucket(session.bucket.id)
            bm.record_fault("sanitized_values", n=n_bad)
            session.ctx.n_sanitized += n_bad    # session_state() visibility
            self._strike(session,
                         f"{n_bad} non-finite/out-of-range LLR values "
                         f"sanitized")
        return arr

    # -- data path --------------------------------------------------------
    def push(self, sid: int, llr) -> None:
        """Feed soft symbols (raw punctured stream for punctured-rate
        sessions) into a session. Validates and sanitizes first (see
        class docstring), then raises Backpressure — BEFORE absorbing
        anything, so a retry is safe — when the session's pending windows
        plus the windows this push would complete exceed queue_depth
        (call step() to drain, then retry; a single push bigger than
        queue_depth chunks must be split by the caller)."""
        session = self._session(sid)
        if self._draining:
            raise Draining(f"push to session {sid}")
        if session.quarantined is not None:
            raise SessionQuarantined(sid, session.quarantined,
                                     session.strikes)
        with self.trace.span("push", sid=sid, bucket=session.bucket.id) as sp:
            if self.faults is not None:
                llr = self.faults.corrupt(llr, sid=sid)
            llr = self._validate_push(session, llr)
            projected = session.ctx.projected_windows(
                session.ctx.incoming_stages(llr))
            if session.inflight + projected > self.queue_depth:
                overshoot = session.inflight + projected - self.queue_depth
                raise Backpressure(
                    f"session {sid}: {session.inflight} windows pending + "
                    f"{projected} in this push > queue_depth="
                    f"{self.queue_depth}; call step() and retry (or split "
                    f"pushes larger than queue_depth chunks)",
                    retry_after_steps=max(1, -(-overshoot // self.slots)))
            sp.set(windows=session.absorb(llr))

    def step(self) -> int:
        """One batched launch per bucket with pending windows, dispatched
        through JAX's async runtime; results materialize ``depth``
        launches behind the dispatch front (the same double buffering the
        single-stream front-end uses), landing on each session's ready
        queue. Returns the number of windows dispatched. Never raises on
        a failed launch — the retry/degrade machinery absorbs it. (The
        fault injector's ``crash_at_step`` hook runs OUTSIDE that
        machinery: an injected crash propagates, as a real process death
        would.)"""
        if self.faults is not None:
            self.faults.crash("step")
        done = 0
        for bucket in list(self._buckets.values()):
            if not bucket.pinned:
                bucket.breaker.step()         # open -> half_open countdown
        for bucket in list(self._buckets.values()):
            if bucket.queue:
                done += self._launch(bucket)
            elif bucket.inflight:
                # an evacuated (or idle) bucket materializes everything it
                # still has in flight — fully, so its bits land on the
                # sessions BEFORE any later window decoded elsewhere
                self._retire(bucket, 0)
        return done

    def _launch(self, bucket: Bucket) -> int:
        """Dispatch one batched launch: up to ``slots`` windows ->
        (k*C, L, beta) frames. The kernel pads the partial batch to the
        plan's tile multiple internally (ops._pad_frames); that padding
        is what the occupancy metric charges — a full-slot steady state
        launches whole tiles only. Does NOT block: the oldest in-flight
        launch beyond ``depth`` is materialized instead."""
        taken = bucket.take(self.slots)
        if not taken:
            return 0
        t_take = time.perf_counter()
        wait = self.metrics.stage("queue_wait_ms")
        for w in taken:
            wait.record((t_take - w.t_enq) * 1e3)
        with self.trace.span("launch", bucket=bucket.id,
                             windows=len(taken)) as sp:
            with self.trace.span("batch_pack", bucket=bucket.id):
                batch = np.concatenate([w.frames for w in taken])
            t_pack = time.perf_counter()
            self.metrics.stage("batch_pack_ms").record(
                (t_pack - t_take) * 1e3)
            sp.set(frames=int(batch.shape[0]))
            self._dispatch(bucket, batch, taken)
            self.metrics.stage("launch_ms").record(
                (time.perf_counter() - t_pack) * 1e3)
        self._retire(bucket, self.depth)
        return len(taken)

    def _ref_fallback(self, bucket: Bucket, nframes: int):
        """The degraded-mode decoder: same trellis/spec, reference
        backend (bit-identical to the kernels at fp32 bm_dtype; bf16
        buckets degrade to the fp32 reference, which is the BER-gated
        direction). Never consults the fault injector — the fallback is
        the path that must work when the fast path doesn't."""
        ref_cfg = dataclasses.replace(bucket.decode_cfg,
                                      backend="reference", renorm_every=1)
        return self.cache.batch_decoder(ref_cfg, nframes, mesh=bucket.mesh)

    # -- circuit breaker / failover ---------------------------------------
    def _evacuate(self, bucket: Bucket) -> None:
        """Move every session (and queued window) of a tripped bucket to
        its failover bucket — pinned to the reference backend on the
        host. Window geometry is identical (same plan), so the pending
        queue transfers losslessly; the ``evacuated`` counter and an
        ``evacuate`` span record the event. The tripped bucket's in-flight
        launches materialize FIRST — per-session bit order must survive
        the handoff."""
        target = self._failover_bucket(bucket)
        moved = len(bucket.sessions)
        self._retire(bucket, 0)
        with self.trace.span("evacuate", bucket=bucket.id, to=target.id,
                             sessions=moved, windows=len(bucket.queue)):
            for sid in list(bucket.sessions):
                session = self._sessions[sid]
                session.bucket = target
                target.sessions.add(sid)
            bucket.sessions.clear()
            target.queue.extend(bucket.queue)
            bucket.queue.clear()
        self.metrics.bucket(bucket.id).record_fault("evacuated", n=moved)

    def _readmit(self, bucket: Bucket, primary: Bucket) -> None:
        """The half-open probe succeeded: the device is back. Move the
        failover bucket's sessions (and any still-queued windows) back to
        the primary fast path — after materializing the failover's
        in-flight launches (probe included), preserving bit order."""
        self._retire(bucket, 0)
        with self.trace.span("readmit", bucket=primary.id,
                             sessions=len(bucket.sessions)):
            for sid in list(bucket.sessions):
                session = self._sessions[sid]
                session.bucket = primary
                primary.sessions.add(sid)
            bucket.sessions.clear()
            primary.queue.extend(bucket.queue)
            bucket.queue.clear()

    def _probe(self, primary: Bucket, bucket: Bucket, dev, batch, taken,
               B: int) -> bool:
        """Half-open probe: try this failover batch on the primary's
        fast path. Success closes the breaker and re-admits the
        sessions; failure re-opens it (a fresh trip) and the caller
        falls back to the pinned reference path."""
        bm = self.metrics.bucket(primary.id)
        try:
            with self.trace.span("breaker_probe", bucket=primary.id,
                                 frames=B):
                if self.faults is not None:
                    self.faults.launch(primary.id)
                out = self.cache.batch_decoder(primary.decode_cfg, B,
                                               mesh=primary.mesh)(dev)
        except Exception as e:                        # noqa: BLE001
            bm.record_fault("launch_errors", error=repr(e))
            if primary.breaker.record_failure():      # half_open -> open
                bm.record_fault("breaker_trips")
                self.trace.event("breaker_open", bucket=primary.id,
                                 probe_failed=True)
            return False
        bucket.inflight.append(
            (out, taken, batch,
             self.trace.begin("inflight", bucket=bucket.id, frames=B,
                              probe=True)))
        if primary.breaker.record_success():          # half_open -> closed
            self.trace.event("breaker_close", bucket=primary.id)
        self._readmit(bucket, primary)
        return True

    def _dispatch(self, bucket: Bucket, batch: np.ndarray, taken) -> None:
        """Dispatch ``batch`` with deadline/retry/degrade plus circuit
        breaking (class docstring). Always appends exactly one in-flight
        launch."""
        B = batch.shape[0]
        bm = self.metrics.bucket(bucket.id)
        dev = jnp.asarray(batch)
        if bucket.pinned:
            # failover path: probe the primary when its breaker is ready,
            # otherwise decode on the pinned reference backend. Neither
            # consults the fault injector — the evacuation target is the
            # path that must work when the fast path doesn't (same
            # contract as _ref_fallback).
            primary = bucket.primary
            if primary is not None \
                    and primary.breaker.state == "half_open" \
                    and self._probe(primary, bucket, dev, batch, taken, B):
                return
            with self.trace.span("launch_attempt", bucket=bucket.id,
                                 pinned=True):
                out = self.cache.batch_decoder(bucket.decode_cfg, B,
                                               mesh=bucket.mesh)(dev)
            bucket.inflight.append(
                (out, taken, batch,
                 self.trace.begin("inflight", bucket=bucket.id, frames=B,
                                  pinned=True)))
            return
        deadline = self.launch_timeout_s
        tripped = False
        for attempt in range(self.max_retries + 1):
            t0 = time.perf_counter()
            try:
                with self.trace.span("launch_attempt", bucket=bucket.id,
                                     attempt=attempt):
                    if self.faults is not None:
                        self.faults.launch(bucket.id)
                    refresh = (self.faults is not None
                               and self.faults.plan_cache_miss())
                    if refresh:
                        bm.record_fault("cache_refreshes")
                    fn = self.cache.batch_decoder(bucket.decode_cfg, B,
                                                  mesh=bucket.mesh,
                                                  refresh=refresh)
                    out = fn(dev)
                    if deadline is not None \
                            and time.perf_counter() - t0 > deadline:
                        raise LaunchTimeout(
                            f"bucket {bucket.id}: launch exceeded "
                            f"{deadline * 1e3:.1f} ms deadline")
                bucket.inflight.append(
                    (out, taken, batch,
                     self.trace.begin("inflight", bucket=bucket.id,
                                      frames=B)))
                if bucket.breaker.state != "open":
                    # a late success after the breaker tripped mid-retry
                    # must NOT reset `consecutive`: the breaker stays
                    # open (only the half-open probe closes it), and its
                    # snapshot should keep reporting the streak that
                    # tripped it, not a misleading 0
                    bucket.breaker.record_success()
                if tripped:           # late success on an open breaker:
                    self._evacuate(bucket)   # still fail over — the
                return                       # probe path re-admits
            except LaunchTimeout as e:
                bm.record_fault("timeouts", error=str(e))
            except Exception as e:                    # noqa: BLE001
                bm.record_fault("launch_errors", error=repr(e))
            if bucket.breaker.record_failure():
                # consecutive failures crossed the threshold: the trip is
                # recorded now, but the remaining retry budget still runs
                # — a degraded window's accounting stays uniform
                # (max_retries+1 attempts, max_retries retries) and a
                # late success still lands the batch on the fast path
                tripped = True
                bm.record_fault("breaker_trips")
                self.trace.event("breaker_open", bucket=bucket.id,
                                 consecutive=bucket.breaker.consecutive)
            if attempt < self.max_retries:
                bm.record_fault("retries")
                self.trace.event("retry", bucket=bucket.id, attempt=attempt)
                if self.backoff_s:
                    time.sleep(self.backoff_s * (2 ** attempt))
        # retries exhausted (or breaker tripped): degrade to the reference
        # fallback so healthy sessions still get (correct) bits — never
        # drop the batch
        bm.record_fault("degraded")
        with self.trace.span("degrade", bucket=bucket.id, frames=B):
            out = self._ref_fallback(bucket, B)(dev)
        bucket.inflight.append(
            (out, taken, batch,
             self.trace.begin("inflight", bucket=bucket.id, frames=B,
                              degraded=True)))
        if tripped or bucket.breaker.state != "closed":
            self._evacuate(bucket)

    def _retire(self, bucket: Bucket, leave: int) -> int:
        """Materialize in-flight launches down to ``leave`` (blocks on the
        OLDEST only), distribute bits to sessions, record metrics. A
        launch whose results fail to materialize (an async error surfacing
        late) is re-decoded synchronously by the reference fallback."""
        C, f = bucket.chunk_frames, bucket.decode_cfg.spec.f
        bm = self.metrics.bucket(bucket.id)
        deadline = self.launch_timeout_s
        done = 0
        while len(bucket.inflight) > leave:
            bits_dev, taken, batch, inflight_span = bucket.inflight.popleft()
            t0 = time.perf_counter()
            with self.trace.span("retire", bucket=bucket.id,
                                 windows=len(taken)):
                try:
                    bits = np.asarray(bits_dev)         # (k*C, f)
                except Exception as e:                  # noqa: BLE001
                    bm.record_fault("launch_errors", error=repr(e))
                    bm.record_fault("degraded")
                    with self.trace.span("degrade", bucket=bucket.id):
                        bits = np.asarray(
                            self._ref_fallback(bucket, batch.shape[0])(
                                jnp.asarray(batch)))
                t_done = time.perf_counter()
                inflight_span.end()
                self.metrics.stage("retire_ms").record((t_done - t0) * 1e3)
                if deadline is not None and t_done - t0 > deadline:
                    # cooperative deadline: a hang shows up here; record it
                    # (the NEXT launch's retry path is where recovery
                    # happens)
                    bm.record_fault(
                        "timeouts",
                        error=f"bucket {bucket.id}: materialize "
                              f"took {(t_done - t0) * 1e3:.1f} ms")
                n_bits = live = 0
                for i, w in enumerate(taken):
                    out = bits[i * C:(i + 1) * C].reshape(-1)[:w.n_bits]
                    w.session.ready.append(out.astype(np.int32, copy=False))
                    n_bits += w.n_bits
                    live += min(C, -(-w.n_bits // f))   # real frames only
                B = len(taken) * C
                bm.record_launch(
                    live_frames=live,                   # zero tail frames
                    pad_frames=B - live + bucket.tile_pad(B),  # as pad
                    windows=len(taken), bits=n_bits,
                    window_latency_ms=[(t_done - w.t_enq) * 1e3
                                       for w in taken])
            done += len(taken)
        return done

    def drain(self, checkpoint: str | None = None, *,
              stop: bool = False) -> int:
        """Dispatch until no bucket has pending windows, then materialize
        every in-flight launch. With ``checkpoint=path`` (or
        ``stop=True``) this is the operational stop-the-world handoff:
        admission and pushes are refused FIRST (``Draining``), the
        pipeline retires completely, and the quiesced server is
        snapshotted — restart elsewhere with ``DecodeServer.restore``."""
        if checkpoint is not None or stop:
            self._draining = True
        done = 0
        while any(b.queue for b in self._buckets.values()):
            done += self.step()
        for bucket in self._buckets.values():
            self._retire(bucket, 0)
        if checkpoint is not None:
            self.checkpoint(checkpoint)
        return done

    def checkpoint(self, path: str) -> str:
        """Write an atomic, CRC-validated snapshot of the whole server to
        ``path`` (serve/checkpoint.py). In-flight launches are retired
        first — the snapshot is a consistent cut; sessions resume
        bit-identically after ``restore``."""
        from .checkpoint import save_checkpoint
        return save_checkpoint(self, path)

    @classmethod
    def restore(cls, path: str, *, mesh=None, cache=None, faults=None,
                trace=None) -> "DecodeServer":
        """Rebuild a server from a checkpoint in a fresh process. The
        process-local collaborators (mesh/cache/faults/trace) are passed
        anew — they are not serializable state. Raises ``CheckpointError``
        on a corrupt, truncated, or version-mismatched file; never
        returns a half-loaded server."""
        from .checkpoint import restore_server
        return restore_server(cls, path, mesh=mesh, cache=cache,
                              faults=faults, trace=trace)

    def poll(self, sid: int) -> np.ndarray:
        """Collect (and clear) a session's bits materialized so far —
        non-blocking; results trail the dispatch front by up to ``depth``
        launches (drain()/close_session force completion). A quarantined
        session raises its structured ``SessionQuarantined`` error
        instead — use ``close_session`` to tear it down and recover any
        bits decoded before quarantine."""
        session = self._session(sid)
        if session.quarantined is not None:
            raise SessionQuarantined(sid, session.quarantined,
                                     session.strikes)
        return session.take_ready()

    def close_session(self, sid: int) -> np.ndarray:
        """Flush the session's tail, decode everything it still has
        pending, free its slot, and return the remaining bits. Works on
        quarantined sessions too (teardown must never be refused)."""
        session = self._session(sid)
        session.finish()
        while session.inflight:
            self._launch(session.bucket)
        self._retire(session.bucket, 0)
        session.closed = True
        session.bucket.sessions.discard(sid)
        # an evacuated (or re-admitted) session may still have launches in
        # flight on its partner bucket — retire those too before teardown
        partner = (session.bucket.primary if session.bucket.pinned
                   else self._buckets.get(session.bucket.key + ("failover",)))
        if partner is not None:
            self._retire(partner, 0)
        del self._sessions[sid]
        return session.take_ready()

    def session_state(self, sid: int) -> dict:
        """Structured per-session health (JSON-ready): strikes,
        quarantine reason, pending windows, sanitizer counters."""
        s = self._session(sid)
        return {"sid": sid, "bucket": s.bucket.id, "strikes": s.strikes,
                "quarantined": s.quarantined, "inflight": s.inflight,
                **s.ctx.numeric_stats()}

    # -- introspection ----------------------------------------------------
    def buckets(self) -> list[Bucket]:
        return list(self._buckets.values())

    def metrics_snapshot(self) -> dict:
        """Per-bucket rows + totals + stage-latency breakdowns +
        plan-cache stats, JSON-ready (the shape the benchmarks' 'serve'
        section records). Totals carry the fault counters, derived
        throughput (``mbps``/``uptime_s``) and overall health;
        ``stages`` holds the queue-wait/pack/launch/retire latency
        summaries; ``quarantined_sessions`` counts live quarantined
        sessions; ``breakers`` carries every primary bucket's circuit
        breaker (state/trips/consecutive); ``checkpoint`` the save/
        restore counts; ``faults`` reports the injector's schedule
        counters when one is attached. ``stages_hist`` carries the same
        stage histograms at full bucket resolution (Prometheus histogram
        shape — cumulative ``[le, count]`` pairs), so a scrape exports
        aggregatable ``_bucket`` series, not just point summaries."""
        snap = {"buckets": self.metrics.snapshot(),
                "totals": self.metrics.totals(),
                "stages": self.metrics.stage_snapshot(),
                "stages_hist": self.metrics.stage_histograms(),
                "plan_cache": self.cache.stats(),
                "sessions": len(self._sessions),
                "quarantined_sessions": sum(
                    1 for s in self._sessions.values()
                    if s.quarantined is not None),
                "breakers": {b.id: b.breaker.snapshot()
                             for b in self._buckets.values()
                             if not b.pinned},
                "checkpoint": {"saves": self.checkpoint_saves,
                               "restores": self.checkpoint_restores},
                "draining": self._draining}
        if self.faults is not None:
            snap["faults"] = self.faults.stats()
        return snap
