"""DecodeServer: a multi-tenant, slot-based Viterbi decode service.

Continuous-batching for receivers instead of language models: sessions
(each a code config + an unbounded LLR stream) are admitted into the
server, grouped into buckets by (trellis, spec, compiled plan), and each
``step()`` packs up to ``slots`` pending chunk windows per bucket into
ONE batched kernel launch (partial batches are padded to the plan's tile
multiple inside the kernel wrapper — ``chunk_frames`` is already a tile
multiple, so a full-slot launch pads nothing). Per-session bits come
back bit-identical to running that session
alone through ``core.stream.stream_decode``: frames decode independently,
and the per-session chunking/flush geometry is exactly the single-stream
context's.

The compiled-plan cache (plan_cache.PLAN_CACHE by default) guarantees
tenant churn never re-compiles: one trace per (trellis, spec, plan,
batch-nframes) bucket for the lifetime of the process.

Flow control is explicit and synchronous:

  * admission — ``open_session`` raises ``ServerFull`` beyond
    ``max_sessions`` live sessions;
  * backpressure — ``push`` raises ``Backpressure`` once a session has
    ``queue_depth`` windows pending (call ``step()`` to drain, then
    retry);
  * ``step()`` runs one launch per bucket with pending work; ``poll``
    collects a session's decoded bits; ``close_session`` flushes the
    tail, drains, and frees the slot.

With ``mesh=...`` every bucket's batch is sharded across the mesh's
devices (distributed/stream.py) — the batch is the frame axis, so the
scale-out story of the single stream carries over unchanged.
"""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from ..core.pipeline import DecoderConfig
from ..core.stream import StreamContext
from .metrics import ServeMetrics
from .plan_cache import PLAN_CACHE, PlanCache
from .scheduler import Bucket, Session, bucket_plan

__all__ = ["DecodeServer", "ServerFull", "Backpressure"]


class ServerFull(RuntimeError):
    """Admission refused: the server is at max_sessions live sessions."""


class Backpressure(RuntimeError):
    """Push refused: the session already has queue_depth windows pending.

    The caller should drive ``step()`` (or ``drain()``) and retry."""


class DecodeServer:
    """Slot-based batching decode service over heterogeneous sessions.

    slots:        max windows batched per bucket per step. A steady-state
                  full bucket launches ``slots * chunk_frames`` frames in
                  one fixed shape — one compile per bucket, regardless of
                  session churn (drain tails add at most one shape per
                  distinct partial batch size, each compiled once).
    max_sessions: admission limit over all buckets.
    queue_depth:  per-session pending-window limit before Backpressure.
    depth:        batched launches allowed in flight per bucket behind
                  the dispatch front (1 = double buffering, as in
                  StreamDecoder; 0 = synchronous, for debugging).
    mesh:         optional 1-D 'frames' mesh — bucket batches are then
                  sharded across its devices.
    cache:        PlanCache override (default: process-global PLAN_CACHE).
    """

    def __init__(self, *, slots: int = 4, max_sessions: int = 64,
                 queue_depth: int = 8, depth: int = 1, mesh=None,
                 cache: PlanCache | None = None):
        assert slots > 0 and max_sessions > 0 and queue_depth > 0
        assert depth >= 0
        self.slots = slots
        self.max_sessions = max_sessions
        self.queue_depth = queue_depth
        self.depth = depth                    # launches left in flight
        self.mesh = mesh
        self.cache = cache if cache is not None else PLAN_CACHE
        self.metrics = ServeMetrics()
        self._sessions: dict[int, Session] = {}
        self._buckets: dict[tuple, Bucket] = {}
        self._next_sid = 0

    # -- admission --------------------------------------------------------
    @property
    def num_sessions(self) -> int:
        return len(self._sessions)

    def open_session(self, cfg: DecoderConfig,
                     chunk_frames: int | None = None) -> int:
        """Admit one tenant; returns its session id. Sessions of the same
        (trellis, spec, plan) — any puncture rate — share a bucket."""
        if len(self._sessions) >= self.max_sessions:
            raise ServerFull(
                f"{len(self._sessions)} live sessions (max_sessions="
                f"{self.max_sessions}); close one or raise the limit")
        ndev = int(self.mesh.devices.size) if self.mesh is not None else 1
        plan = bucket_plan(cfg, num_devices=ndev, chunk_frames=chunk_frames)
        key = (cfg.trellis, cfg.spec, plan.cache_key(), cfg.backend,
               cfg.interpret, self.mesh)
        bucket = self._buckets.get(key)
        if bucket is None:
            bucket = self._buckets[key] = Bucket(key, cfg, plan)
        sid = self._next_sid
        self._next_sid += 1
        ctx = StreamContext(cfg.spec, cfg.trellis.beta, bucket.chunk_frames,
                            cfg.rate)
        session = Session(sid, cfg, ctx, bucket)
        self._sessions[sid] = session
        bucket.sessions.add(sid)
        return sid

    def _session(self, sid: int) -> Session:
        try:
            return self._sessions[sid]
        except KeyError:
            raise KeyError(f"no live session {sid}") from None

    # -- data path --------------------------------------------------------
    def push(self, sid: int, llr) -> None:
        """Feed soft symbols (raw punctured stream for punctured-rate
        sessions) into a session. Raises Backpressure — BEFORE absorbing
        anything, so a retry is safe — when the session's pending windows
        plus the windows this push would complete exceed queue_depth
        (call step() to drain; a single push bigger than queue_depth
        chunks must be split by the caller)."""
        session = self._session(sid)
        projected = session.ctx.projected_windows(
            session.ctx.incoming_stages(llr))
        if session.inflight + projected > self.queue_depth:
            raise Backpressure(
                f"session {sid}: {session.inflight} windows pending + "
                f"{projected} in this push > queue_depth="
                f"{self.queue_depth}; call step() and retry (or split "
                f"pushes larger than queue_depth chunks)")
        session.absorb(llr)

    def step(self) -> int:
        """One batched launch per bucket with pending windows, dispatched
        through JAX's async runtime; results materialize ``depth``
        launches behind the dispatch front (the same double buffering the
        single-stream front-end uses), landing on each session's ready
        queue. Returns the number of windows dispatched."""
        done = 0
        for bucket in self._buckets.values():
            if bucket.queue:
                done += self._launch(bucket)
        return done

    def _launch(self, bucket: Bucket) -> int:
        """Dispatch one batched launch: up to ``slots`` windows ->
        (k*C, L, beta) frames. The kernel pads the partial batch to the
        plan's tile multiple internally (ops._pad_frames); that padding
        is what the occupancy metric charges — a full-slot steady state
        launches whole tiles only. Does NOT block: the oldest in-flight
        launch beyond ``depth`` is materialized instead."""
        taken = bucket.take(self.slots)
        if not taken:
            return 0
        B = len(taken) * bucket.chunk_frames
        batch = np.concatenate([w.frames for w in taken])
        fn = self.cache.batch_decoder(bucket.decode_cfg, B, mesh=self.mesh)
        bucket.inflight.append((fn(jnp.asarray(batch)), taken))
        self._retire(bucket, self.depth)
        return len(taken)

    def _retire(self, bucket: Bucket, leave: int) -> int:
        """Materialize in-flight launches down to ``leave`` (blocks on the
        OLDEST only), distribute bits to sessions, record metrics."""
        C, f = bucket.chunk_frames, bucket.decode_cfg.spec.f
        done = 0
        while len(bucket.inflight) > leave:
            bits_dev, taken = bucket.inflight.popleft()
            bits = np.asarray(bits_dev)                 # (k*C, f)
            t_done = time.perf_counter()
            n_bits = live = 0
            for i, w in enumerate(taken):
                out = bits[i * C:(i + 1) * C].reshape(-1)[:w.n_bits]
                w.session.ready.append(out.astype(np.int32, copy=False))
                n_bits += w.n_bits
                live += min(C, -(-w.n_bits // f))       # real frames only
            B = len(taken) * C
            self.metrics.bucket(bucket.id).record_launch(
                live_frames=live,                       # zero tail frames
                pad_frames=B - live + bucket.tile_pad(B),  # count as pad
                windows=len(taken), bits=n_bits,
                window_latency_ms=[(t_done - w.t_enq) * 1e3 for w in taken])
            done += len(taken)
        return done

    def drain(self) -> int:
        """Dispatch until no bucket has pending windows, then materialize
        every in-flight launch."""
        done = 0
        while any(b.queue for b in self._buckets.values()):
            done += self.step()
        for bucket in self._buckets.values():
            self._retire(bucket, 0)
        return done

    def poll(self, sid: int) -> np.ndarray:
        """Collect (and clear) a session's bits materialized so far —
        non-blocking; results trail the dispatch front by up to ``depth``
        launches (drain()/close_session force completion)."""
        return self._session(sid).take_ready()

    def close_session(self, sid: int) -> np.ndarray:
        """Flush the session's tail, decode everything it still has
        pending, free its slot, and return the remaining bits."""
        session = self._session(sid)
        session.finish()
        while session.inflight:
            self._launch(session.bucket)
        self._retire(session.bucket, 0)
        session.closed = True
        session.bucket.sessions.discard(sid)
        del self._sessions[sid]
        return session.take_ready()

    # -- introspection ----------------------------------------------------
    def buckets(self) -> list[Bucket]:
        return list(self._buckets.values())

    def metrics_snapshot(self) -> dict:
        """Per-bucket rows + totals + plan-cache stats, JSON-ready (the
        shape the benchmarks' 'serve' section records)."""
        return {"buckets": self.metrics.snapshot(),
                "totals": self.metrics.totals(),
                "plan_cache": self.cache.stats(),
                "sessions": len(self._sessions)}
