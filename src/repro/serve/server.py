"""DecodeServer: a multi-tenant, slot-based Viterbi decode service.

Continuous-batching for receivers instead of language models: sessions
(each a code config + an unbounded LLR stream) are admitted into the
server, grouped into buckets by (trellis, spec, compiled plan), and each
``step()`` packs up to ``slots`` pending chunk windows per bucket into
ONE batched kernel launch (partial batches are padded to the plan's tile
multiple inside the kernel wrapper — ``chunk_frames`` is already a tile
multiple, so a full-slot launch pads nothing). Per-session bits come
back bit-identical to running that session
alone through ``core.stream.stream_decode``: frames decode independently,
and the per-session chunking/flush geometry is exactly the single-stream
context's.

The compiled-plan cache (plan_cache.PLAN_CACHE by default) guarantees
tenant churn never re-compiles: one trace per (trellis, spec, plan,
batch-nframes) bucket for the lifetime of the process.

Flow control is explicit and synchronous:

  * admission — ``open_session`` raises ``ServerFull`` beyond
    ``max_sessions`` live sessions;
  * backpressure — ``push`` raises ``Backpressure`` once a session has
    ``queue_depth`` windows pending (call ``step()`` to drain, then
    retry);
  * ``step()`` runs one launch per bucket with pending work; ``poll``
    collects a session's decoded bits; ``close_session`` flushes the
    tail, drains, and frees the slot.

All flow-control and per-session failures derive from ``ServeError``,
which carries a machine-readable ``retry_after_steps`` hint (how many
``step()`` calls should clear the condition; None when retrying won't
help). The server loop itself NEVER dies on a bad tenant or a bad
launch; errors surface on that session's ``push``/``poll``.

Fault tolerance (one poisoned buffer or failed launch must not corrupt
a bucket):

  * input hardening — every ``push`` is validated and (by default)
    sanitized: NaN/Inf become neutral zero LLRs, |llr| > ``llr_clip``
    clamps (core.sanitize; bit-identical on clean inputs). A push that
    fails validation is a STRIKE; after ``quarantine_after`` strikes the
    session is quarantined — further ``push``/``poll`` raise
    ``SessionQuarantined`` (structured: sid/reason/strikes) while
    ``close_session`` still tears it down cleanly.
  * launch deadline + retry — a batched launch that raises, or exceeds
    ``launch_timeout_s`` wall-clock, is retried up to ``max_retries``
    times with exponential backoff (``backoff_s * 2**attempt``).
  * graceful degrade — when retries are exhausted the batch is decoded
    by the reference backend (``backend='reference'``, bit-identical to
    the kernels at fp32) instead of the bucket's compiled fast path, so
    healthy sessions still get correct bits; the bucket's ``degraded``
    counter and ``health`` reflect it. A launch whose results fail to
    materialize in ``_retire`` is re-decoded the same way.
  * observability — per-bucket error/retry/timeout/degraded/quarantine
    counters and a health field in ``metrics_snapshot()``.

``faults=`` accepts a ``repro.testing.faults.FaultInjector`` whose
seeded schedule exercises all of the above deterministically (kernel
exceptions, slow launches, poisoned LLRs, plan-cache evictions); it is
None in production and every hook is pay-nothing when unset. The
injected-slow-launch deadline is cooperative: JAX cannot preempt a
dispatched computation, so the deadline is checked around the dispatch
(and observed again at materialize time) rather than interrupting it.

With ``mesh=...`` every bucket's batch is sharded across the mesh's
devices (distributed/stream.py) — the batch is the frame axis, so the
scale-out story of the single stream carries over unchanged.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np
import jax.numpy as jnp

from ..core.pipeline import DecoderConfig
from ..core.sanitize import LLR_CLIP, sanitize_llr
from ..core.stream import StreamContext
from ..obs.tracer import get_tracer
from .metrics import ServeMetrics
from .plan_cache import PLAN_CACHE, PlanCache
from .scheduler import Bucket, Session, bucket_plan

__all__ = ["DecodeServer", "ServeError", "ServerFull", "Backpressure",
           "PoisonedInput", "SessionQuarantined", "LaunchTimeout"]


class ServeError(RuntimeError):
    """Base class of every serve-layer error.

    ``retry_after_steps`` is a machine-readable hint: how many ``step()``
    calls the caller should drive before retrying the failed operation
    (None = retrying will not help; fix the condition instead)."""

    def __init__(self, msg: str, *, retry_after_steps: int | None = None):
        super().__init__(msg)
        self.retry_after_steps = retry_after_steps


class ServerFull(ServeError):
    """Admission refused: the server is at max_sessions live sessions."""


class Backpressure(ServeError):
    """Push refused: the session already has queue_depth windows pending.

    The caller should drive ``step()`` (``retry_after_steps`` estimates
    how many) and retry."""


class PoisonedInput(ServeError):
    """Push rejected by input validation (malformed shape, or poisoned
    values under the 'raise' sanitize policy). Counts one strike toward
    quarantine; the push absorbed nothing, so a corrected retry is safe."""

    def __init__(self, msg: str, *, sid: int, n_bad: int = 0):
        super().__init__(msg, retry_after_steps=None)
        self.sid = sid
        self.n_bad = n_bad


class SessionQuarantined(ServeError):
    """The session exceeded the validation-failure threshold and is
    quarantined: pushes and polls are refused (structured sid/reason/
    strikes); ``close_session`` still works and returns any bits decoded
    before quarantine."""

    def __init__(self, sid: int, reason: str, strikes: int):
        super().__init__(
            f"session {sid} is quarantined after {strikes} input-validation "
            f"failures (last: {reason}); close_session() to tear it down",
            retry_after_steps=None)
        self.sid = sid
        self.reason = reason
        self.strikes = strikes


class LaunchTimeout(ServeError):
    """A batched launch exceeded the per-launch deadline (internal retry
    signal; surfaces only in bucket metrics/last_error)."""


class DecodeServer:
    """Slot-based batching decode service over heterogeneous sessions.

    slots:        max windows batched per bucket per step. A steady-state
                  full bucket launches ``slots * chunk_frames`` frames in
                  one fixed shape — one compile per bucket, regardless of
                  session churn (drain tails add at most one shape per
                  distinct partial batch size, each compiled once).
    max_sessions: admission limit over all buckets.
    queue_depth:  per-session pending-window limit before Backpressure.
    depth:        batched launches allowed in flight per bucket behind
                  the dispatch front (1 = double buffering, as in
                  StreamDecoder; 0 = synchronous, for debugging).
    mesh:         optional 1-D 'frames' mesh — bucket batches are then
                  sharded across its devices.
    cache:        PlanCache override (default: process-global PLAN_CACHE).
    launch_timeout_s: per-launch wall-clock deadline (None = no deadline).
    max_retries:  re-dispatch attempts after a failed/timed-out launch
                  before degrading to the reference fallback.
    backoff_s:    base retry backoff; attempt i sleeps backoff_s * 2**i.
    sanitize:     push input policy — 'zero' (scrub NaN/Inf, clamp
                  out-of-range; default), 'raise' (reject poisoned
                  pushes), 'off' (trust the tenant).
    llr_clip:     out-of-range magnitude threshold for sanitization.
    quarantine_after: validation-failure strikes before a session is
                  quarantined.
    faults:       optional repro.testing.faults.FaultInjector (tests/CI
                  chaos only; None in production).
    trace:        optional repro.obs.Tracer recording push/launch/retry/
                  retire spans and stage latencies. None (default)
                  resolves to the process-global tracer — a pay-nothing
                  no-op unless ``repro.obs.set_tracer`` enabled one.
    """

    def __init__(self, *, slots: int = 4, max_sessions: int = 64,
                 queue_depth: int = 8, depth: int = 1, mesh=None,
                 cache: PlanCache | None = None,
                 launch_timeout_s: float | None = None,
                 max_retries: int = 2, backoff_s: float = 0.01,
                 sanitize: str = "zero", llr_clip: float = LLR_CLIP,
                 quarantine_after: int = 3, faults=None, trace=None):
        assert slots > 0 and max_sessions > 0 and queue_depth > 0
        assert depth >= 0
        assert max_retries >= 0 and backoff_s >= 0.0
        assert quarantine_after > 0
        assert sanitize in ("zero", "raise", "off")
        self.slots = slots
        self.max_sessions = max_sessions
        self.queue_depth = queue_depth
        self.depth = depth                    # launches left in flight
        self.mesh = mesh
        self.cache = cache if cache is not None else PLAN_CACHE
        self.launch_timeout_s = launch_timeout_s
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.sanitize = sanitize
        self.llr_clip = llr_clip
        self.quarantine_after = quarantine_after
        self.faults = faults
        self.trace = trace if trace is not None else get_tracer()
        self.metrics = ServeMetrics()
        self._sessions: dict[int, Session] = {}
        self._buckets: dict[tuple, Bucket] = {}
        self._next_sid = 0

    # -- admission --------------------------------------------------------
    @property
    def num_sessions(self) -> int:
        return len(self._sessions)

    def open_session(self, cfg: DecoderConfig,
                     chunk_frames: int | None = None) -> int:
        """Admit one tenant; returns its session id. Sessions of the same
        (trellis, spec, plan) — any puncture rate — share a bucket."""
        if len(self._sessions) >= self.max_sessions:
            raise ServerFull(
                f"{len(self._sessions)} live sessions (max_sessions="
                f"{self.max_sessions}); close one or raise the limit")
        ndev = int(self.mesh.devices.size) if self.mesh is not None else 1
        plan = bucket_plan(cfg, num_devices=ndev, chunk_frames=chunk_frames)
        key = (cfg.trellis, cfg.spec, plan.cache_key(), cfg.backend,
               cfg.interpret, self.mesh)
        bucket = self._buckets.get(key)
        if bucket is None:
            bucket = self._buckets[key] = Bucket(key, cfg, plan)
        sid = self._next_sid
        self._next_sid += 1
        # the server sanitizes at ITS push boundary (so strikes/counters
        # land on the session); the context's own scrub is off
        ctx = StreamContext(cfg.spec, cfg.trellis.beta, bucket.chunk_frames,
                            cfg.rate, sanitize="off")
        session = Session(sid, cfg, ctx, bucket)
        self._sessions[sid] = session
        bucket.sessions.add(sid)
        return sid

    def _session(self, sid: int) -> Session:
        try:
            return self._sessions[sid]
        except KeyError:
            raise KeyError(f"no live session {sid}") from None

    # -- input hardening --------------------------------------------------
    def _strike(self, session: Session, reason: str) -> None:
        """One validation failure; quarantine at the threshold."""
        bm = self.metrics.bucket(session.bucket.id)
        session.strikes += 1
        bm.record_fault("poisoned_pushes", error=reason)
        if session.quarantined is None \
                and session.strikes >= self.quarantine_after:
            session.quarantined = reason
            bm.record_fault("quarantined")

    def _validate_push(self, session: Session, llr):
        """Convert + validate + sanitize one push; returns the clean
        array. Strikes (and possibly quarantines) on failure."""
        try:
            arr = np.asarray(llr, np.float32)
        except (TypeError, ValueError) as e:
            reason = f"push is not numeric: {e}"
            self._strike(session, reason)
            raise PoisonedInput(f"session {session.sid}: {reason}",
                                sid=session.sid) from None
        try:
            session.ctx.check_shape(arr)
            if self.sanitize != "off":
                arr, n_bad = sanitize_llr(arr, self.llr_clip, self.sanitize)
            else:
                n_bad = 0
        except ValueError as e:
            self._strike(session, str(e))
            raise PoisonedInput(f"session {session.sid}: {e}",
                                sid=session.sid) from None
        if n_bad:
            # sanitized to safety — still a strike (a tenant repeatedly
            # sending poison gets quarantined even under 'zero' policy)
            bm = self.metrics.bucket(session.bucket.id)
            bm.record_fault("sanitized_values", n=n_bad)
            session.ctx.n_sanitized += n_bad    # session_state() visibility
            self._strike(session,
                         f"{n_bad} non-finite/out-of-range LLR values "
                         f"sanitized")
        return arr

    # -- data path --------------------------------------------------------
    def push(self, sid: int, llr) -> None:
        """Feed soft symbols (raw punctured stream for punctured-rate
        sessions) into a session. Validates and sanitizes first (see
        class docstring), then raises Backpressure — BEFORE absorbing
        anything, so a retry is safe — when the session's pending windows
        plus the windows this push would complete exceed queue_depth
        (call step() to drain, then retry; a single push bigger than
        queue_depth chunks must be split by the caller)."""
        session = self._session(sid)
        if session.quarantined is not None:
            raise SessionQuarantined(sid, session.quarantined,
                                     session.strikes)
        with self.trace.span("push", sid=sid, bucket=session.bucket.id) as sp:
            if self.faults is not None:
                llr = self.faults.corrupt(llr, sid=sid)
            llr = self._validate_push(session, llr)
            projected = session.ctx.projected_windows(
                session.ctx.incoming_stages(llr))
            if session.inflight + projected > self.queue_depth:
                overshoot = session.inflight + projected - self.queue_depth
                raise Backpressure(
                    f"session {sid}: {session.inflight} windows pending + "
                    f"{projected} in this push > queue_depth="
                    f"{self.queue_depth}; call step() and retry (or split "
                    f"pushes larger than queue_depth chunks)",
                    retry_after_steps=max(1, -(-overshoot // self.slots)))
            sp.set(windows=session.absorb(llr))

    def step(self) -> int:
        """One batched launch per bucket with pending windows, dispatched
        through JAX's async runtime; results materialize ``depth``
        launches behind the dispatch front (the same double buffering the
        single-stream front-end uses), landing on each session's ready
        queue. Returns the number of windows dispatched. Never raises on
        a failed launch — the retry/degrade machinery absorbs it."""
        done = 0
        for bucket in self._buckets.values():
            if bucket.queue:
                done += self._launch(bucket)
        return done

    def _launch(self, bucket: Bucket) -> int:
        """Dispatch one batched launch: up to ``slots`` windows ->
        (k*C, L, beta) frames. The kernel pads the partial batch to the
        plan's tile multiple internally (ops._pad_frames); that padding
        is what the occupancy metric charges — a full-slot steady state
        launches whole tiles only. Does NOT block: the oldest in-flight
        launch beyond ``depth`` is materialized instead."""
        taken = bucket.take(self.slots)
        if not taken:
            return 0
        t_take = time.perf_counter()
        wait = self.metrics.stage("queue_wait_ms")
        for w in taken:
            wait.record((t_take - w.t_enq) * 1e3)
        with self.trace.span("launch", bucket=bucket.id,
                             windows=len(taken)) as sp:
            with self.trace.span("batch_pack", bucket=bucket.id):
                batch = np.concatenate([w.frames for w in taken])
            t_pack = time.perf_counter()
            self.metrics.stage("batch_pack_ms").record(
                (t_pack - t_take) * 1e3)
            sp.set(frames=int(batch.shape[0]))
            self._dispatch(bucket, batch, taken)
            self.metrics.stage("launch_ms").record(
                (time.perf_counter() - t_pack) * 1e3)
        self._retire(bucket, self.depth)
        return len(taken)

    def _ref_fallback(self, bucket: Bucket, nframes: int):
        """The degraded-mode decoder: same trellis/spec, reference
        backend (bit-identical to the kernels at fp32 bm_dtype; bf16
        buckets degrade to the fp32 reference, which is the BER-gated
        direction). Never consults the fault injector — the fallback is
        the path that must work when the fast path doesn't."""
        ref_cfg = dataclasses.replace(bucket.decode_cfg,
                                      backend="reference", renorm_every=1)
        return self.cache.batch_decoder(ref_cfg, nframes, mesh=self.mesh)

    def _dispatch(self, bucket: Bucket, batch: np.ndarray, taken) -> None:
        """Dispatch ``batch`` with deadline/retry/degrade (class
        docstring). Always appends exactly one in-flight launch."""
        B = batch.shape[0]
        bm = self.metrics.bucket(bucket.id)
        dev = jnp.asarray(batch)
        deadline = self.launch_timeout_s
        for attempt in range(self.max_retries + 1):
            t0 = time.perf_counter()
            try:
                with self.trace.span("launch_attempt", bucket=bucket.id,
                                     attempt=attempt):
                    if self.faults is not None:
                        self.faults.launch(bucket.id)
                    refresh = (self.faults is not None
                               and self.faults.plan_cache_miss())
                    if refresh:
                        bm.record_fault("cache_refreshes")
                    fn = self.cache.batch_decoder(bucket.decode_cfg, B,
                                                  mesh=self.mesh,
                                                  refresh=refresh)
                    out = fn(dev)
                    if deadline is not None \
                            and time.perf_counter() - t0 > deadline:
                        raise LaunchTimeout(
                            f"bucket {bucket.id}: launch exceeded "
                            f"{deadline * 1e3:.1f} ms deadline")
                bucket.inflight.append(
                    (out, taken, batch,
                     self.trace.begin("inflight", bucket=bucket.id,
                                      frames=B)))
                return
            except LaunchTimeout as e:
                bm.record_fault("timeouts", error=str(e))
            except Exception as e:                    # noqa: BLE001
                bm.record_fault("launch_errors", error=repr(e))
            if attempt < self.max_retries:
                bm.record_fault("retries")
                self.trace.event("retry", bucket=bucket.id, attempt=attempt)
                if self.backoff_s:
                    time.sleep(self.backoff_s * (2 ** attempt))
        # retries exhausted: degrade to the reference fallback so healthy
        # sessions still get (correct) bits — never drop the batch
        bm.record_fault("degraded")
        with self.trace.span("degrade", bucket=bucket.id, frames=B):
            out = self._ref_fallback(bucket, B)(dev)
        bucket.inflight.append(
            (out, taken, batch,
             self.trace.begin("inflight", bucket=bucket.id, frames=B,
                              degraded=True)))

    def _retire(self, bucket: Bucket, leave: int) -> int:
        """Materialize in-flight launches down to ``leave`` (blocks on the
        OLDEST only), distribute bits to sessions, record metrics. A
        launch whose results fail to materialize (an async error surfacing
        late) is re-decoded synchronously by the reference fallback."""
        C, f = bucket.chunk_frames, bucket.decode_cfg.spec.f
        bm = self.metrics.bucket(bucket.id)
        deadline = self.launch_timeout_s
        done = 0
        while len(bucket.inflight) > leave:
            bits_dev, taken, batch, inflight_span = bucket.inflight.popleft()
            t0 = time.perf_counter()
            with self.trace.span("retire", bucket=bucket.id,
                                 windows=len(taken)):
                try:
                    bits = np.asarray(bits_dev)         # (k*C, f)
                except Exception as e:                  # noqa: BLE001
                    bm.record_fault("launch_errors", error=repr(e))
                    bm.record_fault("degraded")
                    with self.trace.span("degrade", bucket=bucket.id):
                        bits = np.asarray(
                            self._ref_fallback(bucket, batch.shape[0])(
                                jnp.asarray(batch)))
                t_done = time.perf_counter()
                inflight_span.end()
                self.metrics.stage("retire_ms").record((t_done - t0) * 1e3)
                if deadline is not None and t_done - t0 > deadline:
                    # cooperative deadline: a hang shows up here; record it
                    # (the NEXT launch's retry path is where recovery
                    # happens)
                    bm.record_fault(
                        "timeouts",
                        error=f"bucket {bucket.id}: materialize "
                              f"took {(t_done - t0) * 1e3:.1f} ms")
                n_bits = live = 0
                for i, w in enumerate(taken):
                    out = bits[i * C:(i + 1) * C].reshape(-1)[:w.n_bits]
                    w.session.ready.append(out.astype(np.int32, copy=False))
                    n_bits += w.n_bits
                    live += min(C, -(-w.n_bits // f))   # real frames only
                B = len(taken) * C
                bm.record_launch(
                    live_frames=live,                   # zero tail frames
                    pad_frames=B - live + bucket.tile_pad(B),  # as pad
                    windows=len(taken), bits=n_bits,
                    window_latency_ms=[(t_done - w.t_enq) * 1e3
                                       for w in taken])
            done += len(taken)
        return done

    def drain(self) -> int:
        """Dispatch until no bucket has pending windows, then materialize
        every in-flight launch."""
        done = 0
        while any(b.queue for b in self._buckets.values()):
            done += self.step()
        for bucket in self._buckets.values():
            self._retire(bucket, 0)
        return done

    def poll(self, sid: int) -> np.ndarray:
        """Collect (and clear) a session's bits materialized so far —
        non-blocking; results trail the dispatch front by up to ``depth``
        launches (drain()/close_session force completion). A quarantined
        session raises its structured ``SessionQuarantined`` error
        instead — use ``close_session`` to tear it down and recover any
        bits decoded before quarantine."""
        session = self._session(sid)
        if session.quarantined is not None:
            raise SessionQuarantined(sid, session.quarantined,
                                     session.strikes)
        return session.take_ready()

    def close_session(self, sid: int) -> np.ndarray:
        """Flush the session's tail, decode everything it still has
        pending, free its slot, and return the remaining bits. Works on
        quarantined sessions too (teardown must never be refused)."""
        session = self._session(sid)
        session.finish()
        while session.inflight:
            self._launch(session.bucket)
        self._retire(session.bucket, 0)
        session.closed = True
        session.bucket.sessions.discard(sid)
        del self._sessions[sid]
        return session.take_ready()

    def session_state(self, sid: int) -> dict:
        """Structured per-session health (JSON-ready): strikes,
        quarantine reason, pending windows, sanitizer counters."""
        s = self._session(sid)
        return {"sid": sid, "bucket": s.bucket.id, "strikes": s.strikes,
                "quarantined": s.quarantined, "inflight": s.inflight,
                **s.ctx.numeric_stats()}

    # -- introspection ----------------------------------------------------
    def buckets(self) -> list[Bucket]:
        return list(self._buckets.values())

    def metrics_snapshot(self) -> dict:
        """Per-bucket rows + totals + stage-latency breakdowns +
        plan-cache stats, JSON-ready (the shape the benchmarks' 'serve'
        section records). Totals carry the fault counters, derived
        throughput (``mbps``/``uptime_s``) and overall health;
        ``stages`` holds the queue-wait/pack/launch/retire latency
        summaries; ``quarantined_sessions`` counts live quarantined
        sessions; ``faults`` reports the injector's schedule counters
        when one is attached."""
        snap = {"buckets": self.metrics.snapshot(),
                "totals": self.metrics.totals(),
                "stages": self.metrics.stage_snapshot(),
                "plan_cache": self.cache.stats(),
                "sessions": len(self._sessions),
                "quarantined_sessions": sum(
                    1 for s in self._sessions.values()
                    if s.quarantined is not None)}
        if self.faults is not None:
            snap["faults"] = self.faults.stats()
        return snap
