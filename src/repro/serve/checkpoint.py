"""Durable checkpoints for the decode service (serve/server.py).

A checkpoint is one JSON document capturing EVERYTHING a fresh process
needs to resume every live stream bit-identically:

  * the server's constructor knobs (``DecodeServer.init_kwargs``) — the
    restored server is configured like the one that saved;
  * every session: its code config (trellis/spec/rate/backend knobs),
    the bounded carry state of its stream context
    (``StreamContext.state_dict`` — overlap buffer, depuncture phase,
    raw remainder, counters), quarantine strikes, and any decoded bits
    the client had not yet polled (bit-packed);
  * every bucket's still-queued windows (the frames a crash would
    otherwise strand between push and launch);
  * every circuit breaker's state and the full metrics state (fault
    counters, latency histograms, accumulated uptime) — the restored
    ``metrics_snapshot()`` tells one continuous story across the crash.

The write is ATOMIC (tmp file + ``os.replace`` — a crash mid-save leaves
the previous checkpoint intact, never a torn file) and SELF-VALIDATING: a
CRC-32 over the canonical payload JSON plus a schema string. The load
path refuses — with a structured ``CheckpointError``, never a half-loaded
server — anything missing, unparseable, schema-mismatched, or failing
its CRC (``testing.faults`` ``checkpoint_corrupt`` drives that rejection
in CI).

Consistency model: ``save_checkpoint`` first retires every in-flight
launch (materializing those bits into the sessions' ready queues), so
the snapshot is a consistent cut — each window is either still queued
(saved raw) or fully decoded (saved as bits); nothing is in between.

What is deliberately NOT saved: compiled plans (the plan cache rebuilds
them from the configs — one trace per bucket, same as a cold start),
meshes/devices, fault injectors, tracers. Those are process-local and
passed fresh to ``DecodeServer.restore``.
"""
from __future__ import annotations

import base64
import dataclasses
import json
import os
import time
import zlib

import numpy as np

from ..core.framed import FrameSpec
from ..core.pipeline import DecoderConfig
from ..core.trellis import make_trellis
from .scheduler import PendingWindow
from .server import ServeError

__all__ = ["CheckpointError", "SCHEMA", "save_checkpoint",
           "load_checkpoint", "restore_server", "encode_cfg", "decode_cfg"]

#: Schema tag written into (and demanded of) every checkpoint file. Bump
#: it when the payload shape changes incompatibly — an old server must
#: refuse a new checkpoint (and vice versa) rather than misread it.
SCHEMA = "repro.serve.checkpoint/v1"


class CheckpointError(ServeError):
    """A checkpoint could not be written or loaded (missing, truncated,
    corrupt, or schema-mismatched file). ``retry_after_steps`` is None:
    retrying won't help — point at a valid checkpoint instead."""

    def __init__(self, msg: str):
        super().__init__(msg, retry_after_steps=None)


# -- config (de)serialization ---------------------------------------------
#: DecoderConfig's plain (JSON-native) fields; trellis and spec are
#: handled structurally.
_CFG_FIELDS = ("rate", "backend", "interpret", "pack_survivors", "radix",
               "frames_per_tile", "layout", "bm_dtype", "renorm_every",
               "block_frames", "overlap")


def encode_cfg(cfg: DecoderConfig) -> dict:
    """JSON-ready form of a DecoderConfig. The trellis serializes as its
    (k, polys) recipe — ``make_trellis`` is lru_cached, so decoding
    returns the canonical instance (identity-hashed, jit-static-safe)."""
    return {"trellis": {"k": cfg.trellis.k,
                        "polys": [int(p) for p in cfg.trellis.polys]},
            "spec": dataclasses.asdict(cfg.spec),
            **{f: getattr(cfg, f) for f in _CFG_FIELDS}}


def decode_cfg(data: dict) -> DecoderConfig:
    trellis = make_trellis(int(data["trellis"]["k"]),
                           tuple(int(p) for p in data["trellis"]["polys"]))
    spec = FrameSpec(**data["spec"])
    # fields absent from older checkpoints take the dataclass default
    # (e.g. block_frames/overlap on pre-block-mode files)
    return DecoderConfig(trellis=trellis, spec=spec,
                         **{f: data[f] for f in _CFG_FIELDS if f in data})


# -- binary payload helpers ------------------------------------------------
def _enc_bits(bits: np.ndarray) -> dict:
    """Decoded bits (0/1 int32) -> bit-packed base64 (~32x smaller than
    JSON int lists)."""
    arr = np.asarray(bits, np.uint8)
    return {"n": int(arr.size),
            "b64": base64.b64encode(np.packbits(arr).tobytes())
                   .decode("ascii")}


def _dec_bits(data: dict) -> np.ndarray:
    raw = np.frombuffer(
        base64.b64decode(data["b64"].encode("ascii"), validate=True),
        np.uint8)
    n = int(data["n"])
    if raw.size * 8 < n:
        raise ValueError(f"bit payload too short: {raw.size * 8} < {n}")
    return np.unpackbits(raw)[:n].astype(np.int32)


def _enc_f32(arr: np.ndarray) -> dict:
    """float32 array -> base64 of little-endian bytes, shape alongside."""
    a = np.ascontiguousarray(arr, dtype="<f4")
    return {"shape": list(a.shape),
            "b64": base64.b64encode(a.tobytes()).decode("ascii")}


def _dec_f32(data: dict) -> np.ndarray:
    raw = base64.b64decode(data["b64"].encode("ascii"), validate=True)
    return (np.frombuffer(raw, dtype="<f4").astype(np.float32)
            .reshape([int(s) for s in data["shape"]]))


def _canonical(payload: dict) -> bytes:
    """The byte string the CRC covers: sorted keys, no whitespace. JSON
    round-trips Python floats exactly (repr-based), so re-encoding the
    parsed payload at load time reproduces these bytes bit-for-bit."""
    return json.dumps(payload, sort_keys=True,
                      separators=(",", ":")).encode("utf-8")


def _fsync_dir(dirpath: str) -> None:
    """Make a completed ``os.replace`` itself durable: fsync the
    containing directory so the new directory entry survives power loss,
    not just the file bytes. Best-effort — platforms whose directories
    cannot be opened or fsynced (e.g. Windows) skip it; the previous
    checkpoint is still intact either way."""
    try:
        fd = os.open(dirpath or ".", os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


# -- save ------------------------------------------------------------------
def save_checkpoint(server, path: str) -> str:
    """Snapshot ``server`` to ``path`` atomically; returns ``path``.

    Retires all in-flight launches first (the consistent cut — see
    module docstring). The server keeps running afterwards; pair with
    ``server.drain(checkpoint=path)`` for the stop-the-world handoff.
    """
    with server.trace.span("checkpoint_save", path=str(path),
                           sessions=len(server._sessions)) as sp:
        for bucket in server.buckets():
            server._retire(bucket, 0)
        sessions = []
        for sid, s in sorted(server._sessions.items()):
            sessions.append({
                "sid": sid,
                "cfg": encode_cfg(s.cfg),
                "chunk_frames": s.chunk_frames_arg,
                "strikes": s.strikes,
                "quarantined": s.quarantined,
                "ready": [_enc_bits(r) for r in s.ready],
                "ctx": s.ctx.state_dict(),
            })
        queues = {}
        for bucket in server.buckets():
            if bucket.queue:
                queues[bucket.id] = [
                    {"sid": w.session.sid, "frames": _enc_f32(w.frames),
                     "n_bits": int(w.n_bits)} for w in bucket.queue]
        payload = {
            "server": server.init_kwargs(),
            "next_sid": server._next_sid,
            "saves": server.checkpoint_saves + 1,
            "restores": server.checkpoint_restores,
            "sessions": sessions,
            "queues": queues,
            "breakers": {b.id: b.breaker.state_dict()
                         for b in server.buckets() if not b.pinned},
            "metrics": server.metrics.state_dict(),
        }
        doc = {"schema": SCHEMA, "crc": zlib.crc32(_canonical(payload)),
               "payload": payload}
        data = json.dumps(doc, sort_keys=True).encode("utf-8")
        if server.faults is not None:
            data = server.faults.checkpoint_bytes(data)
        tmp = f"{path}.tmp"
        with open(tmp, "wb") as fh:
            fh.write(data)
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, path)
        _fsync_dir(os.path.dirname(os.path.abspath(path)))
        server.checkpoint_saves += 1
        sp.set(bytes=len(data))
    return path


# -- load ------------------------------------------------------------------
def load_checkpoint(path: str) -> dict:
    """Read + validate a checkpoint file; returns the payload dict.
    Raises ``CheckpointError`` (missing / not JSON / wrong schema / CRC
    mismatch) — the caller never sees a payload that didn't verify."""
    try:
        with open(path, "rb") as fh:
            raw = fh.read()
    except OSError as e:
        raise CheckpointError(
            f"cannot read checkpoint {path!r}: {e}") from None
    try:
        doc = json.loads(raw.decode("utf-8"))
    except (UnicodeDecodeError, ValueError) as e:
        raise CheckpointError(
            f"checkpoint {path!r} is not valid JSON ({e}); the file is "
            f"truncated or corrupt") from None
    if not isinstance(doc, dict) or "payload" not in doc:
        raise CheckpointError(
            f"checkpoint {path!r} has no payload envelope; not a serve "
            f"checkpoint")
    if doc.get("schema") != SCHEMA:
        raise CheckpointError(
            f"checkpoint {path!r} has schema {doc.get('schema')!r}; this "
            f"server reads {SCHEMA!r} — refusing a cross-version load")
    if zlib.crc32(_canonical(doc["payload"])) != doc.get("crc"):
        raise CheckpointError(
            f"checkpoint {path!r} failed its CRC check — the payload was "
            f"corrupted after write; refusing to half-load it")
    return doc["payload"]


def restore_server(cls, path: str, *, mesh=None, cache=None, faults=None,
                   trace=None):
    """Rebuild a ``cls`` (DecodeServer) instance from ``path``. Invoked
    via ``DecodeServer.restore``; see there for the contract."""
    payload = load_checkpoint(path)
    try:
        srv = cls(mesh=mesh, cache=cache, faults=faults, trace=trace,
                  **payload["server"])
    except (TypeError, AssertionError) as e:
        raise CheckpointError(
            f"checkpoint {path!r} carries unusable server config: "
            f"{e!r}") from None
    with srv.trace.span("checkpoint_restore", path=str(path),
                        sessions=len(payload.get("sessions", ()))):
        try:
            _load_into(srv, payload)
        except (KeyError, ValueError, TypeError, IndexError) as e:
            raise CheckpointError(
                f"checkpoint {path!r} is structurally invalid: "
                f"{e!r}") from None
    srv.checkpoint_restores = int(payload["restores"]) + 1
    return srv


def _load_into(srv, payload: dict) -> None:
    """Populate a freshly constructed server from a verified payload."""
    for row in payload["sessions"]:
        cfg = decode_cfg(row["cfg"])
        sid = srv._admit(cfg, row["chunk_frames"], sid=int(row["sid"]))
        s = srv._sessions[sid]
        s.ctx.load_state(row["ctx"])
        s.strikes = int(row["strikes"])
        s.quarantined = row["quarantined"]
        s.ready = [_dec_bits(d) for d in row["ready"]]
    srv._next_sid = int(payload["next_sid"])
    # breaker states land after admission (buckets now exist); sessions
    # of a bucket whose breaker did not come back closed move straight
    # to its failover bucket — silently: the evacuation already happened
    # in the previous process and its counters are restored below.
    by_id = {b.id: b for b in srv.buckets()}
    for bid, state in payload["breakers"].items():
        bucket = by_id.get(bid)
        if bucket is None:
            # Buckets outlive their last session in the saving server
            # (normal tenant churn: open -> drain -> close leaves the
            # bucket, and its breaker, behind in _buckets), but restore
            # only rebuilds buckets some live session maps to. A breaker
            # with no bucket to land on guards nothing the restored
            # server can reach — drop it. A later open_session of that
            # cfg starts with a fresh closed breaker and re-probes the
            # device, which a process restart warrants anyway.
            continue
        bucket.breaker.load_state(state)
    for bucket in list(srv.buckets()):
        if not bucket.pinned and bucket.breaker.state != "closed" \
                and bucket.sessions:
            target = srv._failover_bucket(bucket)
            for sid in list(bucket.sessions):
                session = srv._sessions[sid]
                session.bucket = target
                target.sessions.add(sid)
            bucket.sessions.clear()
    by_id = {b.id: b for b in srv.buckets()}
    for bid, rows in payload["queues"].items():
        bucket = by_id.get(bid)
        if bucket is None:
            raise ValueError(f"queued windows name unknown bucket {bid!r}")
        for row in rows:
            session = srv._sessions[int(row["sid"])]
            bucket.queue.append(
                PendingWindow(session, _dec_f32(row["frames"]),
                              int(row["n_bits"]), time.perf_counter()))
            session.inflight += 1
    srv.metrics.load_state(payload["metrics"])
    srv.checkpoint_saves = int(payload["saves"])
