from .pipeline import DataConfig, SyntheticLM, make_batch  # noqa: F401
