"""Deterministic synthetic data pipeline.

Same contract a production loader would implement: per-host sharding (each
host materializes only its slice of the global batch), deterministic as a
function of (seed, step) so restarts/elastic rescales replay identically,
and double-buffered prefetch. Tokens come from a counter-based hash (no RNG
state to checkpoint — the step index IS the state, which is what makes
fault-tolerant resume trivial).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from ..configs.base import ModelConfig

__all__ = ["DataConfig", "SyntheticLM", "make_batch"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1
    mode: str = "random"       # 'random' (throughput) | 'learnable' (tests)


def _hash_tokens(seed: int, step: int, rows: np.ndarray, seq: int,
                 vocab: int, mode: str = "random") -> np.ndarray:
    """Counter-hash tokens -> (len(rows), seq). 'learnable' mode emits
    arithmetic progressions (fully predictable -> loss can reach ~0)."""
    base = ((seed * 0x9E3779B97F4A7C15 + (step + 1) * 0xBF58476D1CE4E5B9)
            % 2**64)
    if mode == "learnable":
        start = (rows[:, None].astype(np.int64) * 7 + 3) % vocab
        return ((start + np.arange(seq, dtype=np.int64)[None, :])
                % vocab).astype(np.int32)
    cols = np.arange(seq, dtype=np.uint64)[None, :]
    x = (np.uint64(base)
         + rows[:, None].astype(np.uint64) * np.uint64(0x94D049BB133111EB)
         + cols * np.uint64(0xD6E8FEB86659FD93))
    x ^= x >> np.uint64(30); x *= np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(27); x *= np.uint64(0x94D049BB133111EB)
    x ^= x >> np.uint64(31)
    return (x % np.uint64(vocab)).astype(np.int32)


def make_batch(cfg: ModelConfig, dc: DataConfig, step: int) -> dict:
    """The host-local slice of the global batch for ``step``."""
    per_host = dc.global_batch // dc.num_hosts
    rows = np.arange(dc.host_id * per_host, (dc.host_id + 1) * per_host,
                     dtype=np.int64)
    toks = _hash_tokens(dc.seed, step, rows, dc.seq_len + 1, cfg.vocab,
                        dc.mode)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}
    if cfg.vision_patches:
        rs = np.random.RandomState((dc.seed * 1_000_003 + step) % 2**31)
        batch["vision_embeds"] = rs.randn(
            per_host, cfg.vision_patches, cfg.d_model).astype(np.float32)
        batch["labels"][:, :cfg.vision_patches] = -1   # don't train on patches
    if cfg.family == "encdec":
        rs = np.random.RandomState((dc.seed * 1_000_003 + step) % 2**31)
        batch["frames"] = rs.randn(per_host, dc.seq_len,
                                   cfg.d_model).astype(np.float32)
    return batch


class SyntheticLM:
    """Iterator facade with prefetch-by-construction (hash is O(batch))."""

    def __init__(self, cfg: ModelConfig, dc: DataConfig, start_step: int = 0):
        self.cfg, self.dc, self.step = cfg, dc, start_step

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        b = make_batch(self.cfg, self.dc, self.step)
        self.step += 1
        return b
