"""Convolutional encoder (paper §II-A, Fig. 1a) in JAX."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .trellis import Trellis

__all__ = ["encode", "encode_bits"]


def encode(bits: jax.Array, trellis: Trellis, init_state: int = 0) -> jax.Array:
    """Encode ``bits`` (n,) {0,1} -> (n, beta) coded bits.

    A lax.scan over the FSM. The per-step work is a table lookup, so this is
    bound by the scan itself — fine, the encoder is transmitter-side and not
    the paper's target; it exists to drive the verification system (Fig. 8).
    """
    next_state = jnp.asarray(trellis.next_state)      # (S,2)
    out_bits = jnp.asarray(trellis.out_bits)          # (S,2)
    beta = trellis.beta
    shifts = jnp.arange(beta - 1, -1, -1, dtype=jnp.int32)

    def step(state, b):
        word = out_bits[state, b]
        ns = next_state[state, b]
        sym = (word >> shifts) & 1                     # (beta,) MSB=poly0
        return ns, sym

    _, coded = jax.lax.scan(step, jnp.int32(init_state), bits.astype(jnp.int32))
    return coded                                       # (n, beta)


def encode_bits(bits: np.ndarray, trellis: Trellis) -> np.ndarray:
    """Numpy reference encoder (used as test oracle against ``encode``)."""
    state = 0
    out = np.zeros((len(bits), trellis.beta), dtype=np.int32)
    for t, b in enumerate(np.asarray(bits, dtype=np.int64)):
        word = int(trellis.out_bits[state, b])
        for bi in range(trellis.beta):
            out[t, bi] = (word >> (trellis.beta - 1 - bi)) & 1
        state = int(trellis.next_state[state, b])
    return out
