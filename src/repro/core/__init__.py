"""Paper's contribution: memory-efficient parallel Viterbi decoding."""
from .trellis import Trellis, make_trellis, STD_K7            # noqa: F401
from .encoder import encode                                    # noqa: F401
from .decoder import viterbi_decode, viterbi_forward, viterbi_traceback  # noqa: F401
from .framed import FrameSpec, framed_decode                   # noqa: F401
from .traceback import serial_traceback, parallel_traceback    # noqa: F401
from .puncture import puncture, depuncture, PATTERNS           # noqa: F401
from .pipeline import DecoderConfig, make_decoder, make_frame_decoder  # noqa: F401
from .sanitize import LLR_CLIP, sanitize_llr                   # noqa: F401
from .stream import (StreamContext, StreamDecoder,  # noqa: F401
                     make_stream_decoder, stream_decode)
