"""Trellis (encoder FSM) construction for convolutional codes (beta, 1, k).

All tables are static numpy arrays, computed once from (k, generator
polynomials) and baked into jitted functions / Pallas kernels as constants.

Conventions (DESIGN.md §8):
  state s = (in_{t-1}, ..., in_{t-k+1})           -- k-1 bits, MSB = newest
  word  w = (in_t << (k-1)) | s                   -- k bits
  out bit b = parity(g_b & w)                     -- eq. (1) of the paper
  next state s' = w >> 1 = (in_t << (k-2)) | (s >> 1)
  predecessors of j: {(2j) mod S, (2j+1) mod S}   -- butterfly
  branch input into j: j >> (k-2)                 -- Alg. 2 line 4
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache

import numpy as np

__all__ = ["Trellis", "make_trellis", "STD_K7", "popcount"]


def popcount(x: np.ndarray) -> np.ndarray:
    """Vectorized population count for small ints."""
    x = np.asarray(x, dtype=np.uint32)
    out = np.zeros_like(x)
    while np.any(x):
        out = out + (x & 1)
        x = x >> 1
    return out


@dataclasses.dataclass(frozen=True, eq=False)
class Trellis:
    """Static trellis tables for a (beta, 1, k) convolutional code.

    ``eq=False`` ⇒ identity hash/eq: instances come from the lru_cached
    ``make_trellis``, so identity is canonical and the object is a valid
    jit static argument.
    """

    k: int                     # constraint length
    beta: int                  # output bits per input bit (1/rate)
    polys: tuple               # beta generator polynomials (k-bit ints)

    # -- encoder view: indexed by [state, input_bit] --
    next_state: np.ndarray     # (S, 2) int32
    out_bits: np.ndarray       # (S, 2) int32, beta-bit branch output word

    # -- decoder view: indexed by [state_j, pred 0/1] --
    prev_state: np.ndarray     # (S, 2) int32: {2j mod S, 2j+1 mod S}
    prev_out: np.ndarray       # (S, 2) int32: branch output word on edge i->j
    branch_input: np.ndarray   # (S,)  int32: input bit that leads INTO state j

    # -- branch-metric compression tables (paper §IV-B) --
    # delta(o) = sum_b (-1)^{o[b]} llr[b].  Only 2^beta distinct values per
    # stage; and delta(~o) = -delta(o), so 2^(beta-1) magnitudes suffice.
    # sign table maps an output word o to (index into 2^(beta-1) table, sign).
    bm_index: np.ndarray       # (2^beta,) int32 index into compressed table
    bm_sign: np.ndarray        # (2^beta,) int32 in {+1,-1}
    out_signs: np.ndarray      # (2^beta, beta) float32: (-1)^{o[b]} full table

    @property
    def num_states(self) -> int:
        return 1 << (self.k - 1)

    @property
    def rate_inv(self) -> int:
        return self.beta

    def encode_word(self, state: int, bit: int) -> int:
        return int(self.out_bits[state, bit])


@lru_cache(maxsize=None)
def make_trellis(k: int, polys: tuple) -> Trellis:
    """Build the static trellis for constraint length ``k`` and ``polys``.

    ``polys`` are k-bit integers (e.g. 0o171, 0o133 for the standard K=7
    rate-1/2 code of paper Fig. 1).
    """
    beta = len(polys)
    assert beta >= 2, "beta >= 2 per paper §II-A"
    S = 1 << (k - 1)
    states = np.arange(S, dtype=np.int64)

    next_state = np.zeros((S, 2), dtype=np.int32)
    out_bits = np.zeros((S, 2), dtype=np.int32)
    for b in (0, 1):
        w = (b << (k - 1)) | states                       # k-bit word
        next_state[:, b] = (w >> 1).astype(np.int32)
        word = np.zeros(S, dtype=np.int64)
        for bi, g in enumerate(polys):
            bit = popcount(np.bitwise_and(w, g)) & 1      # parity(g & w)
            # output word stores poly 0 in the MSB position (bit beta-1-bi)
            word |= bit.astype(np.int64) << (beta - 1 - bi)
        out_bits[:, b] = word.astype(np.int32)

    # decoder tables -------------------------------------------------------
    j = states
    j_low = j & ((S >> 1) - 1) if S > 1 else j * 0
    prev_state = np.stack([2 * j_low, 2 * j_low + 1], axis=1).astype(np.int32)
    branch_input = (j >> (k - 2)).astype(np.int32)
    prev_out = np.zeros((S, 2), dtype=np.int32)
    for p in (0, 1):
        prev_out[:, p] = out_bits[prev_state[:, p], branch_input]
    # sanity: next_state[prev_state[j,p], branch_input[j]] == j
    for p in (0, 1):
        assert np.all(next_state[prev_state[:, p], branch_input] == j)

    # branch-metric compression (paper eqs. 7-9) ---------------------------
    n_out = 1 << beta
    half = n_out >> 1
    owords = np.arange(n_out)
    # complement pairs: o and (n_out-1) ^ o have negated metrics (eq. 8)
    bm_index = np.where(owords < half, owords, (n_out - 1) ^ owords).astype(np.int32)
    bm_sign = np.where(owords < half, 1, -1).astype(np.int32)
    # full sign table (-1)^{o[b]}; bit b of the word counts from MSB=poly 0
    out_signs = np.zeros((n_out, beta), dtype=np.float32)
    for o in range(n_out):
        for bi in range(beta):
            bit = (o >> (beta - 1 - bi)) & 1
            out_signs[o, bi] = 1.0 - 2.0 * bit
    return Trellis(
        k=k, beta=beta, polys=tuple(int(p) for p in polys),
        next_state=next_state, out_bits=out_bits,
        prev_state=prev_state, prev_out=prev_out, branch_input=branch_input,
        bm_index=bm_index, bm_sign=bm_sign, out_signs=out_signs,
    )


#: The widely-used standard (2,1,7) code with generators 171, 133 (octal) —
#: paper Fig. 1 and §V-A.
STD_K7 = make_trellis(7, (0o171, 0o133))
