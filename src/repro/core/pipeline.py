"""End-to-end decode API: the paper's full receiver path.

depuncture -> frame -> unified decode (Pallas kernel or pure-JAX reference)
-> stitch. This is the composable module the rest of the framework (examples,
benchmarks, multi-pod launch) calls.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .framed import FrameSpec, framed_decode, frame_llr, decode_frame
from .puncture import depuncture, check_alignment
from .trellis import Trellis, STD_K7

__all__ = ["DecoderConfig", "make_decoder"]


@dataclasses.dataclass(frozen=True)
class DecoderConfig:
    """Everything needed to build a decode function.

    The kernel knobs (pack_survivors / radix / frames_per_tile) default to
    the best-known configuration — bit-packed survivors, two trellis stages
    per scan step, VMEM-budget-autotuned tile size. Every combination is
    bit-identical to the reference backend, so these are pure perf knobs
    (set radix=2, pack_survivors=False, frames_per_tile=8 for the seed
    kernel behavior).
    """
    trellis: Trellis = STD_K7
    spec: FrameSpec = FrameSpec()
    rate: str = "1/2"
    backend: str = "reference"     # 'reference' | 'kernel' | 'kernel_split'
    interpret: bool = True         # Pallas interpret mode (CPU container)
    pack_survivors: bool = True    # bit-pack survivors 32x (kernel backends)
    radix: int = 4                 # 2 | 4 trellis stages per ACS step
    frames_per_tile: int | str = "auto"   # tile size, or VMEM-planned

    def __post_init__(self):
        if self.rate != "1/2":
            check_alignment(self.spec.f, self.spec.v1, self.spec.v2, self.rate)
        if self.radix not in (2, 4):
            raise ValueError(f"radix must be 2 or 4, got {self.radix}")


def make_decoder(cfg: DecoderConfig):
    """Returns decode(llr_or_stream, n) -> (n,) bits, jitted."""

    if cfg.backend == "reference":
        def _decode_frames(frames):
            return jax.vmap(lambda fr: decode_frame(fr, cfg.trellis, cfg.spec))(frames)
    elif cfg.backend in ("kernel", "kernel_split"):
        from ..kernels import ops as kops
        unified = cfg.backend == "kernel"

        def _decode_frames(frames):
            return kops.viterbi_decode_frames(
                frames, cfg.trellis, cfg.spec, unified=unified,
                frames_per_tile=cfg.frames_per_tile,
                pack_survivors=cfg.pack_survivors, radix=cfg.radix,
                interpret=cfg.interpret)
    else:
        raise ValueError(cfg.backend)

    @partial(jax.jit, static_argnums=(1,))
    def decode(stream: jax.Array, n: int) -> jax.Array:
        """stream: punctured soft symbols (m,) for rate!=1/2, or (n,beta)."""
        if cfg.rate != "1/2":
            llr = depuncture(stream, cfg.rate, n)
        else:
            llr = stream if stream.ndim == 2 else stream.reshape(n, -1)
        frames = frame_llr(llr, cfg.spec)             # (F, L, beta)
        bits = _decode_frames(frames)                 # (F, f)
        return bits.reshape(-1)[:n]

    return decode
