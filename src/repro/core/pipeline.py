"""End-to-end decode API: the paper's full receiver path.

depuncture -> frame -> unified decode (Pallas kernel or pure-JAX reference)
-> stitch. This is the composable module the rest of the framework (examples,
benchmarks, multi-pod launch, the streaming front-end in core/stream.py)
calls. ``make_frame_decoder`` exposes the frames->bits core so front-ends
that do their own framing (chunked streams, sharded decode) share one
backend dispatch.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .framed import FrameSpec, framed_decode, frame_llr, decode_frame
from .puncture import depuncture, check_alignment
from .sanitize import LLR_CLIP as _LLR_CLIP
from .trellis import Trellis, STD_K7

__all__ = ["DecoderConfig", "make_decoder", "make_frame_decoder"]


@dataclasses.dataclass(frozen=True)
class DecoderConfig:
    """Everything needed to build a decode function.

    The kernel knobs (pack_survivors / radix / frames_per_tile) default to
    the best-known configuration — bit-packed survivors, two trellis stages
    per scan step, VMEM-budget-autotuned tile size. Every combination is
    bit-identical to the reference backend, so these are pure perf knobs
    (set radix=2, pack_survivors=False, frames_per_tile=8 for the seed
    kernel behavior).

    ``layout`` picks the survivor-memory orientation ('lane' = frames on
    sublanes, the interpret-mode layout; 'sublane' = frames on lanes, the
    Mosaic-native layout whose packing survives hardware lane padding) —
    still bit-exact. ``bm_dtype='bfloat16'`` stores branch metrics
    compressed with float32 path-metric accumulation: the one knob that is
    NOT bit-exact, but BER-neutral to within 1e-3 at Eb/N0 >= 2 dB
    (tests/test_ber.py gates it).

    ``renorm_every`` is the path-metric renormalization period: 1
    (default) subtracts the stage max every ACS stage — the historical
    behavior and what the Pallas kernels always do; N>1 amortizes the max
    reduction over N stages, 0 disables it (reference backend only, for
    the renormalization bit-identity gate in tests/test_faults.py).

    ``block_frames``/``overlap`` engage the intra-frame block-parallel
    decode (kernels/block.py): each frame's f kept stages split into
    block_frames blocks of f/block_frames stages carrying an
    overlap-stage training/truncation region on each side, decoded in
    parallel and merged by truncation. ``"auto"`` engages blocking only
    past BLOCK_LEN_THRESHOLD kept stages; ``overlap=None`` takes the
    ~5*constraint-length default. The second knob besides bf16 that is
    not bit-exact (truncated-traceback approximation, BER-gated to 1e-3
    in tests/test_block.py) — applied by ALL backends, reference
    included, so kernel-vs-reference stays bit-identical under blocking.
    """
    trellis: Trellis = STD_K7
    spec: FrameSpec = FrameSpec()
    rate: str = "1/2"
    backend: str = "reference"     # 'reference' | 'kernel' | 'kernel_split'
    interpret: bool = True         # Pallas interpret mode (CPU container)
    pack_survivors: bool = True    # bit-pack survivors 32x (kernel backends)
    radix: int = 4                 # 2 | 4 trellis stages per ACS step
    frames_per_tile: int | str = "auto"   # tile size, or VMEM-planned
    layout: str = "lane"           # 'lane' | 'sublane' survivor layout
    bm_dtype: str = "float32"      # 'float32' | 'bfloat16' branch metrics
    renorm_every: int = 1          # path-metric renormalization period
    block_frames: int | str = 1    # intra-frame blocks per frame, or 'auto'
    overlap: int | None = None     # block training/truncation stages

    def __post_init__(self):
        if self.rate != "1/2":
            check_alignment(self.spec.f, self.spec.v1, self.spec.v2, self.rate)
        if self.radix not in (2, 4):
            raise ValueError(f"radix must be 2 or 4, got {self.radix}")
        if self.layout not in ("lane", "sublane"):
            raise ValueError(f"layout must be 'lane' or 'sublane', "
                             f"got {self.layout!r}")
        if self.bm_dtype not in ("float32", "bfloat16"):
            raise ValueError(f"bm_dtype must be 'float32' or 'bfloat16', "
                             f"got {self.bm_dtype!r}")
        if self.renorm_every < 0:
            raise ValueError(f"renorm_every must be >= 0, "
                             f"got {self.renorm_every}")
        if self.renorm_every != 1 and self.backend != "reference":
            raise ValueError(
                "renorm_every != 1 requires backend='reference' (the "
                "Pallas kernels renormalize every stage unconditionally)")
        if not (self.block_frames == "auto"
                or (isinstance(self.block_frames, int)
                    and self.block_frames >= 1)):
            raise ValueError(
                f"block_frames must be 'auto' or an int >= 1, "
                f"got {self.block_frames!r}")
        if self.overlap is not None and self.overlap < 0:
            raise ValueError(f"overlap must be >= 0, got {self.overlap}")
        if (self.block_frames not in (1, "auto")
                or self.overlap is not None):
            # explicit knobs: fail at config time with the geometry error,
            # not at first decode (``"auto"`` self-limits to valid splits)
            from ..kernels.block import resolve_block
            resolve_block(self.trellis, self.spec, self.block_frames,
                          self.overlap)


def _build_frame_decoder(cfg: DecoderConfig):
    """Build the backend-dispatch closure (uncached — see
    make_frame_decoder / serve.plan_cache for the shared entry point)."""
    from ..kernels.block import merge_blocks, reframe_blocks, resolve_block
    bf, ov = resolve_block(cfg.trellis, cfg.spec, cfg.block_frames,
                           cfg.overlap)
    if cfg.backend == "reference":
        if bf > 1:
            # the reference path applies the SAME block decomposition as
            # the kernels so kernel-vs-reference stays bit-identical (and
            # serve degrade/failover to reference is decode-equivalent)
            sub = cfg.spec.blocked(bf, ov)

            def decode_frames(frames):
                blocks = reframe_blocks(frames, cfg.spec, bf, ov)
                bits = jax.vmap(
                    lambda fr: decode_frame(fr, cfg.trellis, sub,
                                            cfg.renorm_every))(blocks)
                return merge_blocks(bits, bf)
        else:
            def decode_frames(frames):
                return jax.vmap(
                    lambda fr: decode_frame(fr, cfg.trellis, cfg.spec,
                                            cfg.renorm_every))(frames)
    elif cfg.backend in ("kernel", "kernel_split"):
        from ..kernels import ops as kops
        unified = cfg.backend == "kernel"

        def decode_frames(frames):
            return kops.viterbi_decode_frames(
                frames, cfg.trellis, cfg.spec, unified=unified,
                frames_per_tile=cfg.frames_per_tile,
                pack_survivors=cfg.pack_survivors, radix=cfg.radix,
                layout=cfg.layout, bm_dtype=cfg.bm_dtype,
                block_frames=bf, overlap=ov,
                interpret=cfg.interpret)
    else:
        raise ValueError(cfg.backend)
    return decode_frames


def make_frame_decoder(cfg: DecoderConfig):
    """Returns decode_frames(frames (F, L, beta)) -> (F, f) bits.

    The backend-dispatch core shared by make_decoder, the streaming
    front-end (core/stream.py) and the sharded decoder (distributed/
    stream.py). Not jitted here — callers jit the enclosing computation.
    Memoized per cfg in the process-global compiled-plan cache
    (serve.plan_cache): every caller gets the SAME closure, so enclosing
    jits share their trace cache across tenant churn.
    """
    from ..serve.plan_cache import PLAN_CACHE
    return PLAN_CACHE.frame_decoder(cfg)


def make_decoder(cfg: DecoderConfig):
    """Returns decode(llr_or_stream, n) -> (n,) bits, jitted."""
    _decode_frames = make_frame_decoder(cfg)

    @partial(jax.jit, static_argnums=(1,))
    def decode(stream: jax.Array, n: int) -> jax.Array:
        """stream: punctured soft symbols (m,) for rate!=1/2, or (n,beta)."""
        # in-graph input hardening (core.sanitize): NaN/Inf -> neutral
        # zero, |llr| > clip -> ±clip. Identity on clean in-range inputs,
        # so the clean path stays bit-identical.
        stream = jnp.clip(
            jnp.where(jnp.isfinite(stream), stream, jnp.zeros_like(stream)),
            -_LLR_CLIP, _LLR_CLIP)
        if cfg.rate != "1/2":
            llr = depuncture(stream, cfg.rate, n)
        else:
            llr = stream if stream.ndim == 2 else stream.reshape(n, -1)
        frames = frame_llr(llr, cfg.spec)             # (F, L, beta)
        bits = _decode_frames(frames)                 # (F, f)
        return bits.reshape(-1)[:n]

    return decode
