"""Reference full-sequence Viterbi decoder (paper Alg. 1 + Alg. 2).

This is the exact, serial-traceback algorithm: the baseline row (a) of the
paper's Table I. It is the BER gold standard every framed/parallel variant is
validated against, and the oracle for the Pallas kernels.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .metrics import branch_metrics_half, expand_half
from .trellis import Trellis

__all__ = ["viterbi_forward", "viterbi_traceback", "viterbi_decode"]

NEG = jnp.float32(-1e30)   # "minus infinity" for unreachable-ish inits


def viterbi_forward(llr: jax.Array, trellis: Trellis,
                    sigma0: jax.Array | None = None, radix: int = 2,
                    renorm_every: int = 1):
    """Alg. 1: ACS over all stages.

    Args:
      llr: (n, beta) soft inputs (zero entries are neutral / depunctured).
      sigma0: optional (S,) initial path metrics (zeros = unknown start, as
        in framed decoding; the full decoder biases state 0).
      radix: 2 = one trellis stage per scan step; 4 = two stages fused per
        scan step (half the trip count — mirrors the kernels' radix-4 ACS).
        Each fused half-step performs the identical arithmetic sequence
        (candidates, select, max-normalize), so outputs are bit-identical.
      renorm_every: path-metric renormalization period — subtract the
        stage max every N stages. 1 (default) is the historical per-stage
        normalization (DESIGN §8, also what the Pallas kernels do); 0
        disables it entirely (metrics grow ~|llr|·n — safe only for
        bounded n and sane inputs, the baseline the renormalized path is
        gated bit-identical against on clean streams); N>1 amortizes the
        max reduction over N stages. Only the radix-2 path supports
        N != 1 (the reference backend's path).

    Returns:
      sel:   (n, S) int8 selector bits (0 -> predecessor 2j, 1 -> 2j+1);
             this *is* pi, stored compressed (1 bit of info per cell).
      sigma: (S,) final path metrics (max-normalized per stage).
      amax:  (n,) int32 argmax state per stage (for parallel-traceback
             boundary starts, paper §IV-D second solution).
    """
    S = trellis.num_states
    prev_state = jnp.asarray(trellis.prev_state)      # (S, 2)
    prev_out = jnp.asarray(trellis.prev_out)          # (S, 2)
    bm_half = branch_metrics_half(llr, trellis)       # (n, 2^(beta-1))
    if sigma0 is None:
        sigma0 = jnp.zeros((S,), jnp.float32)
    assert radix in (2, 4), radix
    assert renorm_every >= 0, renorm_every

    if renorm_every != 1:
        # periodic (or disabled) renormalization: the per-stage norm mask
        # rides along the scan. Kept separate from the default path below
        # so renorm_every=1 keeps its exact historical graph.
        assert radix == 2, "renorm_every != 1 requires radix=2 (reference)"
        n = bm_half.shape[0]
        if renorm_every > 0:
            norm_mask = (jnp.arange(n) % renorm_every) == (renorm_every - 1)
        else:
            norm_mask = jnp.zeros((n,), bool)

        def step_renorm(sigma, xs):
            bmh, do_norm = xs
            bm = expand_half(bmh, trellis)
            cand0 = sigma[prev_state[:, 0]] + bm[prev_out[:, 0]]
            cand1 = sigma[prev_state[:, 1]] + bm[prev_out[:, 1]]
            sel = (cand1 >= cand0)
            new = jnp.where(sel, cand1, cand0)
            new = jnp.where(do_norm, new - jnp.max(new), new)
            return new, (sel.astype(jnp.int8),
                         jnp.argmax(new).astype(jnp.int32))

        sigma, (sel, amax) = jax.lax.scan(step_renorm, sigma0,
                                          (bm_half, norm_mask))
        return sel, sigma, amax

    def step(sigma, bmh):
        bm = expand_half(bmh, trellis)                # (2^beta,)
        cand0 = sigma[prev_state[:, 0]] + bm[prev_out[:, 0]]
        cand1 = sigma[prev_state[:, 1]] + bm[prev_out[:, 1]]
        sel = (cand1 >= cand0)                        # Alg.1: ties -> i''
        new = jnp.where(sel, cand1, cand0)
        new = new - jnp.max(new)                      # normalize (DESIGN §8)
        return new, (sel.astype(jnp.int8), jnp.argmax(new).astype(jnp.int32))

    if radix == 4:
        n = bm_half.shape[0]
        n2 = n // 2

        def pair(sigma, bmh2):                        # bmh2: (2, half)
            sigma, (sel_a, am_a) = step(sigma, bmh2[0])
            sigma, (sel_b, am_b) = step(sigma, bmh2[1])
            return sigma, (jnp.stack([sel_a, sel_b]),
                           jnp.stack([am_a, am_b]))

        sigma, (sel, amax) = jax.lax.scan(
            pair, sigma0, bm_half[:2 * n2].reshape(n2, 2, -1))
        sel, amax = sel.reshape(2 * n2, S), amax.reshape(2 * n2)
        if n % 2:                                     # odd-length tail stage
            sigma, (sel_t, am_t) = step(sigma, bm_half[-1])
            sel = jnp.concatenate([sel, sel_t[None]])
            amax = jnp.concatenate([amax, am_t[None]])
        return sel, sigma, amax

    sigma, (sel, amax) = jax.lax.scan(step, sigma0, bm_half)
    return sel, sigma, amax


def viterbi_traceback(sel: jax.Array, trellis: Trellis, start_state: jax.Array,
                      num_steps: int | None = None):
    """Alg. 2: serial traceback from ``start_state`` over all of ``sel``.

    Returns (bits, states): bits[t] is the decoded input bit of stage t;
    states[t] is the survivor state AT stage t (after consuming bit t).
    """
    prev_state = jnp.asarray(trellis.prev_state)
    kshift = trellis.k - 2

    def step(j, sel_t):
        bit = j >> kshift                             # alpha_in into state j
        p = sel_t[j].astype(jnp.int32)
        i = prev_state[j, p]
        return i, (bit, j)

    _, (bits, states) = jax.lax.scan(
        step, start_state.astype(jnp.int32), sel.astype(jnp.int32),
        reverse=True)
    return bits.astype(jnp.int32), states


@partial(jax.jit, static_argnums=(1, 2))
def viterbi_decode(llr: jax.Array, trellis: Trellis,
                   radix: int = 2) -> jax.Array:
    """Full-sequence decode: (n, beta) llr -> (n,) bits. Table I row (a)."""
    S = trellis.num_states
    # the encoder starts in state 0: bias the initial metrics
    sigma0 = jnp.full((S,), NEG).at[0].set(0.0)
    sel, sigma, _ = viterbi_forward(llr, trellis, sigma0, radix)
    start = jnp.argmax(sigma).astype(jnp.int32)
    bits, _ = viterbi_traceback(sel, trellis, start)
    return bits
