"""Branch metrics (paper §II-B eq. 2 and §IV-B optimizations).

delta_t(o) = sum_b (-1)^{o[b]} * llr_t[b]   for an output word o (beta bits).

Per stage there are only 2^beta distinct metrics ("repetitive patterns"),
and for standard codes delta(~o) = -delta(o) (eq. 8), so only 2^(beta-1)
values need to be computed/stored (eq. 9) — half the shared-memory (VMEM)
footprint.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .trellis import Trellis

__all__ = ["branch_metrics_full", "branch_metrics_half", "expand_half"]


def branch_metrics_full(llr: jax.Array, trellis: Trellis) -> jax.Array:
    """(n, beta) llr -> (n, 2^beta) metrics for every output word (eq. 7)."""
    signs = jnp.asarray(trellis.out_signs)            # (2^beta, beta)
    return llr.astype(jnp.float32) @ signs.T          # (n, 2^beta)


def branch_metrics_half(llr: jax.Array, trellis: Trellis) -> jax.Array:
    """(n, beta) llr -> (n, 2^(beta-1)) compressed metrics (eqs. 8-9)."""
    half = 1 << (trellis.beta - 1)
    signs = jnp.asarray(trellis.out_signs[:half])     # (2^(beta-1), beta)
    return llr.astype(jnp.float32) @ signs.T


def expand_half(bm_half: jax.Array, trellis: Trellis) -> jax.Array:
    """Reconstruct the full (.., 2^beta) table from the compressed half."""
    idx = jnp.asarray(trellis.bm_index)               # (2^beta,)
    sgn = jnp.asarray(trellis.bm_sign).astype(bm_half.dtype)
    return bm_half[..., idx] * sgn
