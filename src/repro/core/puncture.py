"""Puncturing / de-puncturing (paper §IV-E).

Standard DVB/GSM-style puncturing patterns over the rate-1/2 mother code.
A pattern is a (beta, period) 0/1 mask; 0-marked symbols are dropped by the
transmitter and re-inserted as neutral zero-LLRs by the receiver
("depuncturing" — zeros contribute nothing to eq. 2's branch metrics).

Frames must start at a pattern boundary (paper: f, v1, v2 multiples of the
mask period) — enforced by ``check_alignment``.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

__all__ = ["PATTERNS", "puncture", "depuncture", "check_alignment",
           "punctured_rate"]

# pattern[b, t]: keep output bit b at phase t (mother code beta=2)
PATTERNS: dict[str, np.ndarray] = {
    "1/2": np.array([[1], [1]], dtype=np.int32),
    "2/3": np.array([[1, 1], [1, 0]], dtype=np.int32),
    "3/4": np.array([[1, 1, 0], [1, 0, 1]], dtype=np.int32),
}


def punctured_rate(name: str) -> float:
    p = PATTERNS[name]
    return p.shape[1] / p.sum()


def _mask_for(n: int, pattern: np.ndarray) -> np.ndarray:
    beta, period = pattern.shape
    reps = -(-n // period)
    return np.tile(pattern, (1, reps)).T[:n]          # (n, beta)


def puncture(coded: jax.Array, name: str) -> jax.Array:
    """(n, beta) symbols -> (m,) punctured flat stream (static shapes)."""
    pattern = PATTERNS[name]
    n = coded.shape[0]
    mask = _mask_for(n, pattern).reshape(-1).astype(bool)   # (n*beta,)
    flat = coded.reshape(-1)
    # static-shape compaction: the kept positions are known at trace time
    keep_idx = np.nonzero(mask)[0]
    return flat[jnp.asarray(keep_idx)]


def depuncture(stream: jax.Array, name: str, n: int) -> jax.Array:
    """(m,) received symbols -> (n, beta) llr grid with neutral zeros.

    Parallel: a single static scatter (every thread/lane handles its own
    symbols independently, as in the paper's GPU version).
    """
    pattern = PATTERNS[name]
    mask = _mask_for(n, pattern).reshape(-1).astype(bool)
    keep_idx = np.nonzero(mask)[0]
    assert stream.shape[0] == keep_idx.shape[0], (
        f"stream length {stream.shape[0]} != expected {keep_idx.shape[0]}")
    flat = jnp.zeros((n * pattern.shape[0],), stream.dtype)
    flat = flat.at[jnp.asarray(keep_idx)].set(stream)
    return flat.reshape(n, pattern.shape[0])


def check_alignment(f: int, v1: int, v2: int, name: str) -> None:
    """Paper §IV-E: f, v1, v2 must be multiples of the pattern period so all
    frames start at a mask boundary (avoids block divergence)."""
    period = PATTERNS[name].shape[1]
    for nm, v in (("f", f), ("v1", v1), ("v2", v2)):
        if v % period:
            raise ValueError(f"{nm}={v} not a multiple of pattern period "
                             f"{period} for rate {name}")
