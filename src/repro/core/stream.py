"""Streaming decode front-end: unbounded LLR streams, chunk by chunk.

``viterbi_decode_frames`` and ``make_decoder`` are single-shot: they want
the whole stream in memory, framed, before the first kernel launches. A
receiver does not work like that — samples arrive forever. This module
chunks an unbounded (n, beta) LLR stream into frame batches, keeps the
v1/v2 overlap context across chunk boundaries (so the chunked decode is
BIT-IDENTICAL to the single-shot framed decode of the same stream), and
double-buffers the per-chunk kernel dispatch:

  * chunk i is dispatched through JAX's async runtime and NOT waited on;
  * the host immediately frames chunk i+1 while the device decodes i;
  * results are materialized one chunk behind the dispatch front, so a
    ``block_until_ready`` never sits between two kernel launches.

Geometry: a chunk covers ``chunk_frames * spec.f`` kept stages; the decode
window around it is ``[start - v1, end + v2)``. The rolling buffer always
retains the v1 left-context samples of the NEXT chunk, the flush pads the
final partial chunk with zero LLRs (neutral, exactly like frame_llr's edge
padding), and the stream start is zero-padded the same way — hence the
bit-exact equivalence with ``framed_decode``.

The chunk size and kernel configuration come from one
``kernels.autotune.plan_decode`` plan (the "full plan the front-end
executes"): tiles from the per-device VMEM budget, chunks as a multiple of
tiles x devices so a sharded decode (distributed/stream.py) keeps every
device busy every chunk.
"""
from __future__ import annotations

import collections

import numpy as np
import jax
import jax.numpy as jnp

from .pipeline import DecoderConfig, make_frame_decoder

__all__ = ["StreamDecoder", "make_stream_decoder", "stream_decode"]


class StreamDecoder:
    """Incremental decoder: ``push`` LLR samples, collect decoded bits.

    push() returns the bits whose chunks have *completed* (possibly an
    empty array — results trail the dispatch front by ``depth`` chunks);
    flush() decodes the zero-padded tail and drains everything pending.
    The instance is reusable after flush(). Feed depunctured (m, beta)
    soft symbols (for punctured rates, depuncture before pushing — the
    pattern alignment is stream-global, not per-chunk).
    """

    def __init__(self, cfg: DecoderConfig, decode_frames, chunk_frames: int,
                 depth: int = 1):
        assert chunk_frames > 0 and depth >= 0
        self.cfg = cfg
        self.spec = cfg.spec
        self.beta = cfg.trellis.beta
        self.chunk_frames = chunk_frames
        self.depth = depth                      # chunks left in flight
        self._decode_frames = decode_frames
        self._decoders = {}                     # nframes -> jitted window fn
        self._reset()

    def _reset(self):
        v1 = self.spec.v1
        # the buffer holds [next_chunk_start - v1, ...); the stream start
        # gets the same zero left-context frame_llr would pad with
        self._buf = np.zeros((v1, self.beta), np.float32)
        self._inflight = collections.deque()    # (device_array, n_bits)
        self._n_in = 0                          # stages pushed
        self._n_disp = 0                        # bits dispatched

    def _window_decoder(self, nframes: int):
        """Jitted window -> bits for a chunk of ``nframes`` frames (cached
        per length on the instance: every full chunk shares one
        compilation; flush tails compile once per distinct tail length)."""
        if nframes in self._decoders:
            return self._decoders[nframes]
        spec = self.spec
        L, f = spec.frame_len, spec.f
        decode_frames = self._decode_frames

        @jax.jit
        def run(window):                        # (v1 + nframes*f + v2, beta)
            starts = jnp.arange(nframes) * f
            idx = starts[:, None] + jnp.arange(L)[None, :]
            frames = window[idx]                # (nframes, L, beta)
            return decode_frames(frames).reshape(-1)

        self._decoders[nframes] = run
        return run

    def _dispatch(self, window: np.ndarray, nframes: int, n_bits: int):
        bits = self._window_decoder(nframes)(jnp.asarray(window))
        self._inflight.append((bits, n_bits))
        self._n_disp += n_bits

    def _drain(self, leave: int) -> list[np.ndarray]:
        out = []
        while len(self._inflight) > leave:
            bits, n_bits = self._inflight.popleft()
            out.append(np.asarray(bits)[:n_bits])   # blocks on OLDEST only
        return out

    def push(self, llr) -> np.ndarray:
        """Feed (m, beta) (or flat (m*beta,)) soft symbols; returns the
        decoded bits of every chunk that has completed so far."""
        llr = np.asarray(llr, np.float32).reshape(-1, self.beta)
        self._n_in += llr.shape[0]
        self._buf = np.concatenate([self._buf, llr]) if llr.size \
            else self._buf
        spec, C = self.spec, self.chunk_frames
        ck = C * spec.f                          # kept stages per chunk
        need = spec.v1 + ck + spec.v2            # full decode window
        out = []
        while self._buf.shape[0] >= need:
            self._dispatch(self._buf[:need], C, ck)
            self._buf = self._buf[ck:]           # keep next chunk's v1 lead
            out.extend(self._drain(self.depth))
        return (np.concatenate(out) if out
                else np.zeros((0,), np.int32))

    def flush(self) -> np.ndarray:
        """Decode the zero-padded tail, drain all in-flight chunks, and
        reset for the next stream. Returns the remaining decoded bits."""
        spec = self.spec
        tail = self._n_in - self._n_disp         # stages not yet dispatched
        if tail > 0:
            nframes = -(-tail // spec.f)
            need = spec.v1 + nframes * spec.f + spec.v2
            window = self._buf
            if window.shape[0] < need:           # frame_llr's edge padding
                pad = np.zeros((need - window.shape[0], self.beta),
                               np.float32)
                window = np.concatenate([window, pad])
            self._dispatch(window[:need], nframes, tail)
        out = self._drain(0)
        self._reset()
        return (np.concatenate(out) if out
                else np.zeros((0,), np.int32))


def make_stream_decoder(cfg: DecoderConfig, *, chunk_frames: int | None = None,
                        mesh=None, depth: int = 1) -> StreamDecoder:
    """Build a StreamDecoder for ``cfg``.

    chunk_frames: frames per chunk; default comes from
      kernels.autotune.plan_decode — two kernel tiles per device, so the
      dispatch pipeline and (if ``mesh`` is given) every device stay busy.
    mesh: optional jax Mesh with a 'frames' axis; chunks are then decoded
      with the sharded frame decoder (distributed/stream.py), frames tiled
      across the mesh devices.
    depth: chunks allowed in flight behind the dispatch front (1 = classic
      double buffering; 0 = synchronous, for debugging).
    """
    num_devices = int(mesh.devices.size) if mesh is not None else 1
    if chunk_frames is None:
        from ..kernels.autotune import plan_decode
        plan = plan_decode(
            cfg.trellis, cfg.spec, unified=cfg.backend != "kernel_split",
            pack_survivors=cfg.pack_survivors, radix=cfg.radix,
            bm_dtype=cfg.bm_dtype, layout=cfg.layout,
            num_devices=num_devices)
        chunk_frames = plan.chunk_frames
    if mesh is not None:
        from ..distributed.stream import make_sharded_frame_decoder
        decode_frames = make_sharded_frame_decoder(cfg, mesh)
    else:
        decode_frames = make_frame_decoder(cfg)
    return StreamDecoder(cfg, decode_frames, chunk_frames, depth)


def stream_decode(cfg: DecoderConfig, llr, n: int | None = None, *,
                  chunk_frames: int | None = None, mesh=None,
                  push_size: int | None = None) -> np.ndarray:
    """Convenience one-call wrapper: stream ``llr`` through a
    StreamDecoder in ``push_size``-stage pushes and return the first n
    bits — bit-identical to ``make_decoder(cfg)(llr, n)``. Like
    make_decoder, a punctured-rate cfg takes the punctured symbol stream
    (and needs ``n``); it is depunctured up front because the pattern
    alignment is stream-global."""
    llr = np.asarray(llr, np.float32)
    if cfg.rate != "1/2":
        if n is None:
            raise ValueError("n is required for punctured rates")
        from .puncture import depuncture
        llr = np.asarray(depuncture(jnp.asarray(llr.reshape(-1)),
                                    cfg.rate, n))
    if n is None:
        n = llr.shape[0]
    dec = make_stream_decoder(cfg, chunk_frames=chunk_frames, mesh=mesh)
    if push_size is None:
        push_size = max(1, dec.chunk_frames) * cfg.spec.f
    parts = [dec.push(llr[i:i + push_size])
             for i in range(0, llr.shape[0], push_size)]
    parts.append(dec.flush())
    return np.concatenate(parts)[:n]
