"""Streaming decode front-end: unbounded LLR streams, chunk by chunk.

``viterbi_decode_frames`` and ``make_decoder`` are single-shot: they want
the whole stream in memory, framed, before the first kernel launches. A
receiver does not work like that — samples arrive forever. This module
chunks an unbounded (n, beta) LLR stream into frame batches, keeps the
v1/v2 overlap context across chunk boundaries (so the chunked decode is
BIT-IDENTICAL to the single-shot framed decode of the same stream), and
double-buffers the per-chunk kernel dispatch:

  * chunk i is dispatched through JAX's async runtime and NOT waited on;
  * the host immediately frames chunk i+1 while the device decodes i;
  * results are materialized one chunk behind the dispatch front, so a
    ``block_until_ready`` never sits between two kernel launches.

The per-session state — the rolling v1/v2 overlap buffer, the
stream-global depuncture phase, and the chunk/flush window extraction —
lives in ``StreamContext``, separate from the dispatch machinery, so the
multi-tenant serve layer (repro.serve) can run one context per session
and batch the extracted windows of MANY sessions into a single kernel
launch. ``StreamDecoder`` is the single-session composition: one context
plus the double-buffered dispatch front.

Geometry: a chunk covers ``chunk_frames * spec.f`` kept stages; the decode
window around it is ``[start - v1, end + v2)``. The rolling buffer always
retains the v1 left-context samples of the NEXT chunk, the flush pads the
final partial chunk with zero LLRs (neutral, exactly like frame_llr's edge
padding), and the stream start is zero-padded the same way — hence the
bit-exact equivalence with ``framed_decode``.

Punctured rates are depunctured INSIDE ``push``: the context tracks the
stream-global pattern phase, so callers feed the raw punctured symbol
stream in arbitrary slices (the historical footgun — callers having to
depuncture the whole stream up front because alignment is stream-global —
is gone). Zero-LLR insertion is incremental and bit-identical to the
one-shot ``puncture.depuncture`` of the whole stream.

The chunk size and kernel configuration come from one
``kernels.autotune.plan_decode`` plan (the "full plan the front-end
executes"): tiles from the per-device VMEM budget, chunks as a multiple of
tiles x devices so a sharded decode (distributed/stream.py) keeps every
device busy every chunk. Window decoders are compiled once per
(trellis, spec, plan, nframes) in the process-global plan cache
(serve.plan_cache), so building a second StreamDecoder for the same
configuration — tenant churn — never re-traces.
"""
from __future__ import annotations

import base64
import collections
import dataclasses
import zlib

import numpy as np
import jax.numpy as jnp

from .pipeline import DecoderConfig
from .puncture import PATTERNS
from .sanitize import LLR_CLIP, sanitize_llr

__all__ = ["StreamContext", "StreamDecoder", "Window", "make_stream_decoder",
           "stream_decode", "STATE_VERSIONS"]

#: ``StreamContext.state_dict`` schema versions this build can write AND
#: read back. v1 stores the carry arrays as plain JSON lists (readable,
#: large); v2 stores them as base64 little-endian float32 bytes with a
#: CRC over the binary payload. Both round-trip bit-exactly.
STATE_VERSIONS = (1, 2)


def _enc_f32(arr: np.ndarray) -> str:
    """float32 array -> base64 of its little-endian bytes (bit-exact)."""
    return base64.b64encode(
        np.ascontiguousarray(arr, dtype="<f4").tobytes()).decode("ascii")


def _dec_f32(data: str, shape: tuple) -> np.ndarray:
    raw = base64.b64decode(data.encode("ascii"), validate=True)
    arr = np.frombuffer(raw, dtype="<f4").astype(np.float32)
    return arr.reshape(shape)


@dataclasses.dataclass(frozen=True)
class Window:
    """One extracted decode window: ``window`` spans
    ``[chunk_start - v1, chunk_end + v2)`` stages; decoding it yields
    ``nframes * f`` bits of which the first ``n_bits`` are real (the rest
    is flush padding)."""
    window: np.ndarray        # (v1 + nframes*f + v2, beta) float32
    nframes: int
    n_bits: int

    def frames(self, spec) -> np.ndarray:
        """Frame the window host-side: (nframes, L, beta). Pure gather —
        identical values to the jitted in-graph framing, so a batch built
        from these frames decodes bit-identically."""
        starts = np.arange(self.nframes) * spec.f
        idx = starts[:, None] + np.arange(spec.frame_len)[None, :]
        return self.window[idx]


class StreamContext:
    """Per-session chunking state, extracted from StreamDecoder so the
    serve layer can batch windows across sessions.

    Holds the rolling overlap buffer (always retaining the v1 left
    context of the next chunk), the pushed/emitted stage counters, and —
    for punctured rates — the raw-symbol remainder plus the stream-global
    pattern phase. ``append`` absorbs raw input; ``take_windows`` yields
    every complete chunk window; ``flush_window`` zero-pads and yields the
    final partial chunk (or None if nothing is pending).

    The context is also the stream's numeric-robustness carry: every
    ``append`` validates the push shape and (``sanitize='zero'``, the
    default) scrubs NaN/Inf to neutral zero LLRs and clamps |llr| >
    ``llr_clip`` — bit-identical on clean inputs, with the cumulative
    scrub count in ``n_sanitized``/``numeric_stats()``. Per-stage
    path-metric renormalization inside each window's forward pass
    (DecoderConfig.renorm_every) plus this input clamp is what keeps an
    UNBOUNDED stream's metrics bounded in fp32/bf16 no matter how long
    the session lives. ``sanitize='raise'`` rejects poisoned pushes
    instead (the serve layer's strict-tenant policy); ``'off'`` skips the
    scan (the serve layer pre-sanitizes at its own boundary).
    """

    def __init__(self, spec, beta: int, chunk_frames: int, rate: str = "1/2",
                 *, sanitize: str = "zero", llr_clip: float = LLR_CLIP):
        assert chunk_frames > 0
        self.spec = spec
        self.beta = beta
        self.chunk_frames = chunk_frames
        self.rate = rate
        self.sanitize = sanitize
        self.llr_clip = llr_clip
        self.reset()

    def reset(self):
        # the buffer holds [next_chunk_start - v1, ...); the stream start
        # gets the same zero left-context frame_llr would pad with
        self._buf = np.zeros((self.spec.v1, self.beta), np.float32)
        self._raw = np.zeros((0,), np.float32)  # punctured symbols pending
        self._phase = 0                         # stages depunctured so far
        self.n_in = 0                           # stages appended
        self.n_out = 0                          # bits covered by windows
        self.n_sanitized = 0                    # poisoned values scrubbed

    def check_shape(self, llr: np.ndarray) -> None:
        """Reject structurally invalid pushes with a clear error (the raw
        reshape inside ``append`` would raise something cryptic)."""
        if llr.ndim > 2:
            raise ValueError(
                f"push must be flat or (m, beta); got shape {llr.shape}")
        if self.rate == "1/2" and llr.size % self.beta != 0:
            raise ValueError(
                f"rate-1/2 push of {llr.size} values is not a multiple of "
                f"beta={self.beta} soft symbols per stage")
        if llr.ndim == 2 and llr.shape[1] != self.beta:
            raise ValueError(
                f"2-D push must have beta={self.beta} columns; "
                f"got shape {llr.shape}")

    def numeric_stats(self) -> dict:
        """Cumulative numeric-hardening counters for this stream."""
        return {"stages_in": self.n_in, "bits_out": self.n_out,
                "sanitized_values": self.n_sanitized}

    # -- durable sessions: versioned carry-state serialization -------------
    def _geometry(self) -> dict:
        """The identity a saved state must match to be loadable: a state
        restored into a context of different frame geometry would decode
        different bits, so the mismatch is an error, never a best-effort
        load."""
        return {"f": self.spec.f, "v1": self.spec.v1, "v2": self.spec.v2,
                "beta": self.beta, "chunk_frames": self.chunk_frames,
                "rate": self.rate}

    def state_dict(self, version: int = 2) -> dict:
        """The session's complete carry state, JSON-ready and versioned.

        This is everything a fresh process needs to resume the stream
        BIT-IDENTICALLY: the rolling v1/v2 overlap buffer, the pending
        raw punctured tail, the stream-global depuncture phase, and the
        pushed/emitted/sanitized counters. The truncated-traceback
        insight (arXiv 1608.00066) is why this works and why it is
        small: frame m's decode depends only on the window
        ``[m*f - v1, (m+1)*f + v2)``, so a bounded carry window is all
        the state a session ever needs — ``load_state`` + replaying the
        not-yet-pushed input reproduces the uninterrupted stream's
        output exactly (tests/test_checkpoint.py gates the bit
        identity)."""
        if version not in STATE_VERSIONS:
            raise ValueError(f"unknown StreamContext state version "
                             f"{version}; this build writes {STATE_VERSIONS}")
        state = {"version": version, "geometry": self._geometry(),
                 "phase": int(self._phase), "n_in": int(self.n_in),
                 "n_out": int(self.n_out),
                 "n_sanitized": int(self.n_sanitized),
                 "buf_rows": int(self._buf.shape[0]),
                 "raw_len": int(self._raw.shape[0])}
        if version == 1:
            state["buf"] = [float(x) for x in self._buf.reshape(-1)]
            state["raw"] = [float(x) for x in self._raw]
        else:
            buf_b64 = _enc_f32(self._buf)
            raw_b64 = _enc_f32(self._raw)
            state["buf"] = buf_b64
            state["raw"] = raw_b64
            state["crc"] = zlib.crc32(
                (buf_b64 + "|" + raw_b64).encode("ascii"))
        return state

    def load_state(self, state: dict) -> None:
        """Restore a ``state_dict`` into this context (which must have
        the same geometry). Validates version, geometry, and — for v2
        states — the carry CRC before touching any field, so a corrupt
        or mismatched state never half-loads."""
        try:
            version = state["version"]
            geometry = state["geometry"]
        except (TypeError, KeyError) as e:
            raise ValueError(
                f"not a StreamContext state dict (missing {e})") from None
        if version not in STATE_VERSIONS:
            raise ValueError(
                f"unsupported StreamContext state version {version!r}; "
                f"this build reads {STATE_VERSIONS}")
        if geometry != self._geometry():
            raise ValueError(
                f"state geometry {geometry} does not match this context's "
                f"{self._geometry()}; restoring it would decode different "
                f"bits")
        buf_rows, raw_len = int(state["buf_rows"]), int(state["raw_len"])
        if version == 1:
            buf = np.asarray(state["buf"], np.float32).reshape(
                buf_rows, self.beta)
            raw = np.asarray(state["raw"], np.float32).reshape(raw_len)
        else:
            crc = zlib.crc32(
                (state["buf"] + "|" + state["raw"]).encode("ascii"))
            if crc != state.get("crc"):
                raise ValueError(
                    f"StreamContext state CRC mismatch (stored "
                    f"{state.get('crc')}, computed {crc}): the carry "
                    f"buffers are corrupt")
            try:
                buf = _dec_f32(state["buf"], (buf_rows, self.beta))
                raw = _dec_f32(state["raw"], (raw_len,))
            except ValueError as e:
                raise ValueError(
                    f"StreamContext carry buffers undecodable: {e}") \
                    from None
        # all fields validated — commit atomically
        self._buf = buf
        self._raw = raw
        self._phase = int(state["phase"])
        self.n_in = int(state["n_in"])
        self.n_out = int(state["n_out"])
        self.n_sanitized = int(state["n_sanitized"])

    # -- depuncturing (stream-global phase) -------------------------------
    def _stage_counts(self, t_max: int) -> np.ndarray:
        """Kept symbols per stage for the next ``t_max`` stages (cyclic in
        the pattern period, offset by the stream-global phase)."""
        pat = PATTERNS[self.rate]
        per_stage = pat.sum(axis=0)             # kept symbols at phase t
        return per_stage[(self._phase + np.arange(t_max)) % pat.shape[1]]

    def _depuncture(self, final: bool) -> np.ndarray:
        """Convert buffered raw symbols into complete (s, beta) stages.

        Bit-identical to one-shot ``puncture.depuncture`` of the whole
        stream: punctured positions become neutral zero LLRs. ``final``
        also emits a trailing stage the remainder only partly fills
        (missing kept symbols become zeros — an erased tail)."""
        pat = PATTERNS[self.rate]
        period = pat.shape[1]
        r = self._raw.shape[0]
        if r == 0:
            return np.zeros((0, self.beta), np.float32)
        t_max = r + period                       # >= any reachable stage count
        cum = np.cumsum(self._stage_counts(t_max))
        s = int(np.searchsorted(cum, r, side="right"))
        if final and (s == 0 or cum[s - 1] < r):
            s += 1                               # partial last stage
        if s == 0:
            return np.zeros((0, self.beta), np.float32)
        used = int(min(cum[s - 1], r))
        p0 = self._phase % period
        mask = np.tile(pat, (1, -(-(p0 + s) // period))).T[p0:p0 + s]
        flat = np.zeros((s * self.beta,), np.float32)
        flat[np.flatnonzero(mask.reshape(-1))[:used]] = self._raw[:used]
        self._raw = self._raw[used:]
        self._phase += s
        return flat.reshape(s, self.beta)

    # -- input / window extraction ----------------------------------------
    def append(self, llr) -> int:
        """Absorb raw input; returns the number of stages added.

        rate 1/2: (m, beta) or flat (m*beta,) soft symbols.
        punctured: the raw punctured symbol stream, flat, any slice size —
        the pattern alignment is tracked here, stream-globally."""
        llr = np.asarray(llr, np.float32)
        self.check_shape(llr)
        if self.sanitize != "off":
            llr, n_bad = sanitize_llr(llr, self.llr_clip, self.sanitize)
            self.n_sanitized += n_bad
        if self.rate != "1/2":
            self._raw = np.concatenate([self._raw, llr.reshape(-1)])
            staged = self._depuncture(final=False)
        else:
            staged = llr.reshape(-1, self.beta)
        if staged.size:
            self._buf = np.concatenate([self._buf, staged])
            self.n_in += staged.shape[0]
        return staged.shape[0]

    def incoming_stages(self, llr) -> int:
        """Stages ``append(llr)`` would add — exact, including the
        punctured-rate phase and raw remainder (the serve layer's
        backpressure check runs BEFORE absorbing anything)."""
        llr = np.asarray(llr)
        if self.rate == "1/2":
            return llr.size // self.beta
        r = self._raw.shape[0] + llr.size
        if r == 0:
            return 0
        cum = np.cumsum(self._stage_counts(r + PATTERNS[self.rate].shape[1]))
        return int(np.searchsorted(cum, r, side="right"))

    def projected_windows(self, add_stages: int) -> int:
        """Complete chunk windows extractable once ``add_stages`` more
        stages arrive (counting what is already buffered)."""
        buf_after = self._buf.shape[0] + add_stages
        return max(0, (buf_after - self.spec.v1 - self.spec.v2)
                   // (self.chunk_frames * self.spec.f))

    def take_windows(self) -> list[Window]:
        """Every complete chunk window currently extractable."""
        spec, C = self.spec, self.chunk_frames
        ck = C * spec.f                          # kept stages per chunk
        need = spec.v1 + ck + spec.v2            # full decode window
        out = []
        while self._buf.shape[0] >= need:
            out.append(Window(self._buf[:need], C, ck))
            self._buf = self._buf[ck:]           # keep next chunk's v1 lead
            self.n_out += ck
        return out

    def _stage_raw_tail(self):
        """Flush-time prelude: convert any leftover raw punctured symbols
        (including a partly-filled final stage) into buffered stages."""
        if self.rate != "1/2" and self._raw.size:
            staged = self._depuncture(final=True)
            if staged.size:
                self._buf = np.concatenate([self._buf, staged])
                self.n_in += staged.shape[0]

    def flush_window(self) -> Window | None:
        """The zero-padded final partial chunk (frame_llr's edge padding)
        as ONE window of ceil(tail/f) frames — possibly more than
        ``chunk_frames`` when the last chunk was only missing its v2
        right context. None when every pushed stage is already covered.
        Resets nothing — call ``reset`` to reuse the context."""
        self._stage_raw_tail()
        spec = self.spec
        tail = self.n_in - self.n_out            # stages not yet windowed
        if tail <= 0:
            return None
        nframes = -(-tail // spec.f)
        need = spec.v1 + nframes * spec.f + spec.v2
        window = self._buf
        if window.shape[0] < need:
            pad = np.zeros((need - window.shape[0], self.beta), np.float32)
            window = np.concatenate([window, pad])
        self.n_out += tail
        return Window(window[:need], nframes, tail)

    def flush_chunks(self) -> list[Window]:
        """Flush for the serve layer: the tail as a SEQUENCE of full
        ``chunk_frames`` windows (zero-padded at the stream end), each
        carrying its share of ``n_bits`` — so a bucket keeps its one
        window geometry no matter how long the tail is (it can exceed one
        chunk by up to v2-1 stages of missing right context). The windows
        decode bit-identically to flush_window's single window: frame m's
        decode region depends only on the zero-extended stream."""
        self._stage_raw_tail()
        spec, C = self.spec, self.chunk_frames
        tail = self.n_in - self.n_out
        if tail <= 0:
            return []
        ck = C * spec.f
        nwin = -(-tail // ck)
        need = spec.v1 + nwin * ck + spec.v2
        if self._buf.shape[0] < need:
            pad = np.zeros((need - self._buf.shape[0], self.beta),
                           np.float32)
            self._buf = np.concatenate([self._buf, pad])
        out = []
        for _ in range(nwin):
            n_bits = min(ck, tail)
            out.append(Window(self._buf[:spec.v1 + ck + spec.v2], C, n_bits))
            self._buf = self._buf[ck:]
            tail -= n_bits
            self.n_out += n_bits
        return out


class StreamDecoder:
    """Incremental decoder: ``push`` LLR samples, collect decoded bits.

    push() returns the bits whose chunks have *completed* (possibly an
    empty array — results trail the dispatch front by ``depth`` chunks);
    flush() decodes the zero-padded tail and drains everything pending.
    The instance is reusable after flush(). Feed (m, beta) soft symbols,
    or — for punctured rates — the raw punctured symbol stream (the
    context depunctures in-stream; see StreamContext).
    """

    def __init__(self, cfg: DecoderConfig, chunk_frames: int, *,
                 depth: int = 1, mesh=None, decode_frames=None, cache=None,
                 faults=None, sanitize: str = "zero", trace=None):
        assert chunk_frames > 0 and depth >= 0
        self.cfg = cfg
        self.spec = cfg.spec
        self.beta = cfg.trellis.beta
        self.chunk_frames = chunk_frames
        self.depth = depth                      # chunks left in flight
        self.mesh = mesh
        self._decode_frames = decode_frames     # explicit override only
        self._local_fns = {}                    # override path: per-instance
        if cache is None:
            from ..serve.plan_cache import PLAN_CACHE as cache
        self._cache = cache
        # tracing hook (repro.obs): chunk dispatches become sync spans and
        # each in-flight chunk an ASYNC span spanning dispatch ->
        # materialize, so the double-buffer overlap is visible as
        # concurrent spans in the exported trace. None resolves to the
        # process-global tracer (a pay-nothing no-op unless enabled).
        if trace is None:
            from ..obs.tracer import get_tracer
            trace = get_tracer()
        self.trace = trace
        # fault-injection hook (repro.testing.faults) — None in production.
        # The single-stream front-end has no retry machinery: an injected
        # launch fault propagates to the caller (the multi-tenant server
        # is the layer that retries/degrades).
        self._faults = faults
        self._ctx = StreamContext(cfg.spec, self.beta, chunk_frames,
                                  cfg.rate, sanitize=sanitize)
        self._inflight = collections.deque()    # (device_array, n_bits)

    def _window_decoder(self, nframes: int):
        """Jitted window -> bits for a chunk of ``nframes`` frames. Comes
        from the process-global plan cache — every StreamDecoder (and
        serve bucket) of the same (trellis, spec, plan, nframes) shares
        ONE compilation; flush tails compile once per distinct length. An
        explicit decode_frames override has no cacheable identity, so it
        is memoized per instance instead (one compile per length, as
        before the cache existed)."""
        if self._decode_frames is not None:
            fn = self._local_fns.get(nframes)
            if fn is None:
                from ..serve.plan_cache import build_window_fn
                fn = build_window_fn(self.cfg.spec, self._decode_frames,
                                     nframes)
                self._local_fns[nframes] = fn
            return fn
        return self._cache.window_decoder(self.cfg, nframes, mesh=self.mesh)

    def _dispatch(self, w: Window):
        with self.trace.span("dispatch", nframes=w.nframes,
                             n_bits=w.n_bits):
            if self._faults is not None:
                self._faults.launch("stream")
            bits = self._window_decoder(w.nframes)(jnp.asarray(w.window))
        # async span: dispatch -> materialize; overlapping chunk spans ARE
        # the double buffering, rendered as overlap by the Chrome exporter
        self._inflight.append(
            (bits, w.n_bits,
             self.trace.begin("chunk", nframes=w.nframes, n_bits=w.n_bits)))

    def _drain(self, leave: int) -> list[np.ndarray]:
        out = []
        while len(self._inflight) > leave:
            bits, n_bits, chunk_span = self._inflight.popleft()
            out.append(np.asarray(bits)[:n_bits])   # blocks on OLDEST only
            chunk_span.end()
        return out

    def push(self, llr) -> np.ndarray:
        """Feed soft symbols; returns the decoded bits of every chunk that
        has completed so far. The context validates the push shape and
        sanitizes NaN/Inf/out-of-range values (see StreamContext)."""
        with self.trace.span("push"):
            if self._faults is not None:
                llr = self._faults.corrupt(llr)
            self._ctx.append(llr)
            out = []
            for w in self._ctx.take_windows():
                self._dispatch(w)
                out.extend(self._drain(self.depth))
        return (np.concatenate(out) if out
                else np.zeros((0,), np.int32))

    def flush(self) -> np.ndarray:
        """Decode the zero-padded tail, drain all in-flight chunks, and
        reset for the next stream. Returns the remaining decoded bits."""
        with self.trace.span("flush"):
            w = self._ctx.flush_window()
            if w is not None:
                self._dispatch(w)
            out = self._drain(0)
            self._ctx.reset()
        return (np.concatenate(out) if out
                else np.zeros((0,), np.int32))

    def numeric_stats(self) -> dict:
        """The context's cumulative numeric-hardening counters."""
        return self._ctx.numeric_stats()


def make_stream_decoder(cfg: DecoderConfig, *, chunk_frames: int | None = None,
                        mesh=None, depth: int = 1, cache=None, faults=None,
                        trace=None) -> StreamDecoder:
    """Build a StreamDecoder for ``cfg``.

    chunk_frames: frames per chunk; default comes from
      kernels.autotune.plan_decode — two kernel tiles per device, so the
      dispatch pipeline and (if ``mesh`` is given) every device stay busy.
    mesh: optional jax Mesh with a 'frames' axis; chunks are then decoded
      with the sharded frame decoder (distributed/stream.py), frames tiled
      across the mesh devices.
    depth: chunks allowed in flight behind the dispatch front (1 = classic
      double buffering; 0 = synchronous, for debugging).
    cache: plan cache override (default: the process-global PLAN_CACHE).
    faults: optional repro.testing.faults.FaultInjector (test harness).
    trace: optional repro.obs.Tracer (None = the process-global tracer,
      a no-op unless ``repro.obs.set_tracer`` enabled one).
    """
    num_devices = int(mesh.devices.size) if mesh is not None else 1
    if chunk_frames is None:
        from ..kernels.autotune import plan_decode
        plan = plan_decode(
            cfg.trellis, cfg.spec, unified=cfg.backend != "kernel_split",
            pack_survivors=cfg.pack_survivors, radix=cfg.radix,
            bm_dtype=cfg.bm_dtype, layout=cfg.layout,
            num_devices=num_devices,
            block_frames=cfg.block_frames, overlap=cfg.overlap)
        chunk_frames = plan.chunk_frames
    return StreamDecoder(cfg, chunk_frames, depth=depth, mesh=mesh,
                         cache=cache, faults=faults, trace=trace)


def stream_decode(cfg: DecoderConfig, llr, n: int | None = None, *,
                  chunk_frames: int | None = None, mesh=None,
                  push_size: int | None = None) -> np.ndarray:
    """Convenience one-call wrapper: stream ``llr`` through a
    StreamDecoder in ``push_size``-sized pushes and return the first n
    bits — bit-identical to ``make_decoder(cfg)(llr, n)``. Like
    make_decoder, a punctured-rate cfg takes the raw punctured symbol
    stream (and needs ``n``); it is depunctured in-stream by the decoder's
    StreamContext (push_size then counts raw symbols)."""
    llr = np.asarray(llr, np.float32)
    if cfg.rate != "1/2":
        if n is None:
            raise ValueError("n is required for punctured rates")
        llr = llr.reshape(-1)                    # raw punctured symbols
    else:
        llr = llr.reshape(-1, cfg.trellis.beta)
    if n is None:
        n = llr.shape[0]
    dec = make_stream_decoder(cfg, chunk_frames=chunk_frames, mesh=mesh)
    if push_size is None:
        push_size = max(1, dec.chunk_frames) * cfg.spec.f
    parts = [dec.push(llr[i:i + push_size])
             for i in range(0, llr.shape[0], push_size)]
    parts.append(dec.flush())
    return np.concatenate(parts)[:n]
