"""Framed (tiled) parallel Viterbi decoding (paper §III Fig. 2, §IV).

The n-stage stream is cut into F = ceil(n/f) frames. Frame m decodes output
stages [m*f, (m+1)*f) but *processes* stages [m*f - v1, m*f + f + v2): the
left overlap v1 warms up the path metrics, the right overlap v2 lets the
survivor path converge before the kept region (paper Fig. 2b). Frames are
embarrassingly parallel: vmap here, grid axis in the Pallas kernel, and the
sharded axis in the multi-pod launch.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .decoder import viterbi_forward
from .traceback import parallel_traceback, serial_traceback
from .trellis import Trellis

__all__ = ["FrameSpec", "frame_llr", "decode_frame", "framed_decode",
           "reframe_blocks", "merge_blocks"]


@dataclasses.dataclass(frozen=True)
class FrameSpec:
    """Tiling parameters (paper notation)."""
    f: int = 256          # kept stages per frame
    v1: int = 20          # left overlap (warm-up)
    v2: int = 20          # right overlap (traceback convergence)
    f0: int = 0           # subframe length for parallel traceback (0 = serial)
    v2s: int = 0          # subframe overlap (parallel traceback)
    start: str = "boundary"   # parallel-traceback start-state strategy

    @property
    def frame_len(self) -> int:       # L = v1 + f + v2
        return self.v1 + self.f + self.v2

    @property
    def parallel_tb(self) -> bool:
        return self.f0 > 0

    def num_frames(self, n: int) -> int:
        return -(-n // self.f)

    def validate(self):
        if self.parallel_tb:
            if self.f % self.f0 != 0:
                raise ValueError(
                    f"f={self.f} is not a multiple of f0={self.f0}; the "
                    f"parallel traceback needs f % f0 == 0 (paper §IV-E)")
            if self.v2s > self.v2:
                raise ValueError(
                    f"v2s={self.v2s} exceeds v2={self.v2}; the subframe "
                    f"convergence overlap must fit in the frame overlap")

    def blocked(self, block_frames: int, overlap: int) -> "FrameSpec":
        """The per-block FrameSpec of the intra-frame block-parallel
        decode: each frame's f kept stages split into ``block_frames``
        blocks of ``f / block_frames`` stages, every block carrying an
        ``overlap``-stage training region on the left (metric warm-up)
        and truncation region on the right (traceback convergence) — the
        standard block-based truncated-traceback construction (arXiv
        1608.00066). Blocks are just shorter frames, so the derived spec
        is decoded by the unchanged frame machinery; a parallel-traceback
        geometry carries over (f0 must divide the block, v2s must fit the
        block overlap)."""
        B, ov = int(block_frames), int(overlap)
        if B < 1:
            raise ValueError(f"block_frames must be >= 1, got {block_frames}")
        if ov < 0:
            raise ValueError(f"overlap must be >= 0, got {overlap}")
        if self.f % B != 0:
            raise ValueError(
                f"f={self.f} is not a multiple of block_frames={B}; "
                f"intra-frame blocking needs f % block_frames == 0")
        fb = self.f // B
        if self.parallel_tb:
            if fb % self.f0 != 0:
                raise ValueError(
                    f"block length f/block_frames={fb} is not a multiple "
                    f"of f0={self.f0}; shrink f0 or use fewer blocks")
            if self.v2s > ov:
                raise ValueError(
                    f"v2s={self.v2s} exceeds the block overlap={ov}; the "
                    f"subframe convergence region must fit in it")
        sub = FrameSpec(f=fb, v1=ov, v2=ov,
                        f0=self.f0 if self.parallel_tb else 0,
                        v2s=self.v2s if self.parallel_tb else 0,
                        start=self.start)
        sub.validate()
        return sub


def frame_llr(llr: jax.Array, spec: FrameSpec) -> jax.Array:
    """(n, beta) -> (F, L, beta) overlapping frames, zero-padded at edges.

    Zero LLR is neutral to the metrics — identical to how de-puncturing
    treats erased symbols (paper §IV-E), so edge padding is BER-safe.
    """
    n, beta = llr.shape
    F = spec.num_frames(n)
    pad_r = F * spec.f + spec.v2 - n
    padded = jnp.pad(llr, ((spec.v1, pad_r), (0, 0)))
    starts = jnp.arange(F) * spec.f
    idx = starts[:, None] + jnp.arange(spec.frame_len)[None, :]
    return padded[idx]                                # (F, L, beta)


def decode_frame(llr_frame: jax.Array, trellis: Trellis,
                 spec: FrameSpec, renorm_every: int = 1) -> jax.Array:
    """Decode one (L, beta) frame -> (f,) bits. Pure-JAX reference path.

    ``renorm_every`` is the path-metric renormalization period (see
    viterbi_forward; 1 = the historical per-stage normalization)."""
    sel, sigma, amax = viterbi_forward(                     # uniform sigma0
        llr_frame, trellis, renorm_every=renorm_every)
    if spec.parallel_tb:
        return parallel_traceback(sel, amax, trellis, spec.v1, spec.f,
                                  spec.f0, spec.v2s, spec.start)
    start = jnp.argmax(sigma).astype(jnp.int32)
    return serial_traceback(sel, trellis, start, spec.v1, spec.f)


def reframe_blocks(frames: jax.Array, spec: FrameSpec, block_frames: int,
                   overlap: int) -> jax.Array:
    """(F, L, beta) frames -> (F*B, fb + 2*overlap, beta) block windows.

    Block b of a frame covers frame stages
    ``[v1 + b*fb - overlap, v1 + (b+1)*fb + overlap)`` — its fb kept
    stages plus the training/truncation overlaps — gathered exactly like
    ``frame_llr`` gathers frames from the stream, with zero padding where
    a window reaches past the frame (zero LLR is metric-neutral, the same
    edge treatment as frame_llr / depuncturing). When
    ``overlap <= min(v1, v2)`` every window lies inside the frame and the
    blocked decode is bit-identical to re-framing the stream with
    ``spec.blocked(block_frames, overlap)``."""
    F = frames.shape[0]
    B, ov = int(block_frames), int(overlap)
    fb = spec.f // B
    pad_l = max(0, ov - spec.v1)
    pad_r = max(0, ov - spec.v2)
    padded = jnp.pad(frames, ((0, 0), (pad_l, pad_r), (0, 0)))
    starts = pad_l + spec.v1 - ov + jnp.arange(B) * fb
    idx = starts[:, None] + jnp.arange(fb + 2 * ov)[None, :]
    blocks = padded[:, idx]                           # (F, B, Lb, beta)
    return blocks.reshape(F * B, fb + 2 * ov, frames.shape[2])


def merge_blocks(bits: jax.Array, block_frames: int) -> jax.Array:
    """(F*B, fb) per-block kept bits -> (F, f) frame bits. The trailing
    overlap was already truncated by the per-block decode (a block keeps
    only its fb body stages), so the merge is a pure reshape."""
    FB, fb = bits.shape
    return bits.reshape(FB // int(block_frames), int(block_frames) * fb)


@partial(jax.jit, static_argnums=(1, 2, 3))
def framed_decode(llr: jax.Array, trellis: Trellis, spec: FrameSpec,
                  n_out: int | None = None) -> jax.Array:
    """Full framed decode: (n, beta) llr -> (n,) bits (vmap over frames)."""
    spec.validate()
    n = llr.shape[0] if n_out is None else n_out
    frames = frame_llr(llr, spec)                     # (F, L, beta)
    bits = jax.vmap(lambda fr: decode_frame(fr, trellis, spec))(frames)
    return bits.reshape(-1)[:n]
