"""Traceback strategies (paper §IV-D).

Two tracebacks over one frame's survivor selectors ``sel`` (L, S):

* ``serial_traceback``   — one cursor chases the whole frame (prior work).
* ``parallel_traceback`` — the frame's kept region is split into ``nsub``
  subframes of ``f0`` stages; every subframe is traced back concurrently,
  each with a right-overlap of ``v2s`` stages for survivor-path convergence
  (paper Fig. 5). Start states are either the per-stage argmax states
  recorded in the forward pass (``start='boundary'``, the paper's preferred
  solution) or a fixed state (``start='fixed'``, reproduces Fig. 11's
  degradation).

The parallel version is a *vectorized pointer chase*: all ``nsub`` cursors
advance together, so the backward pass costs f0+v2s vector steps instead of
f+v2 serial steps — the D/D' parallelism of Table I row (c).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .trellis import Trellis

__all__ = ["serial_traceback", "parallel_traceback"]


def serial_traceback(sel: jax.Array, trellis: Trellis, start_state: jax.Array,
                     v1: int, f: int, packed: bool = False) -> jax.Array:
    """Chase from the last stage; return the f kept bits [v1, v1+f).

    ``packed=True`` reads sel as (L, ceil(S/32)) int32 bit-packed selector
    words (kernels/packing.py layout) instead of (L, S) one-per-cell.
    """
    prev_state = jnp.asarray(trellis.prev_state)
    kshift = trellis.k - 2

    def step(j, sel_t):
        bit = j >> kshift
        if packed:
            p = (sel_t[j >> 5] >> (j & 31)) & 1
        else:
            p = sel_t[j]
        i = prev_state[j, p]
        return i, bit

    _, bits = jax.lax.scan(step, start_state.astype(jnp.int32),
                           sel.astype(jnp.int32), reverse=True)
    return jax.lax.dynamic_slice(bits, (v1,), (f,))


def parallel_traceback(sel: jax.Array, amax: jax.Array, trellis: Trellis,
                       v1: int, f: int, f0: int, v2s: int,
                       start: str = "boundary",
                       packed: bool = False) -> jax.Array:
    """Parallel traceback over ``nsub = f // f0`` subframes.

    Args:
      sel:  (L, S) selector bits from the forward pass, L >= v1 + f + v2s.
      amax: (L,) per-stage argmax states (used when start == 'boundary').
      v1/f: kept region is stages [v1, v1+f).
      f0:   subframe length (f % f0 == 0).
      v2s:  subframe right-overlap (convergence) length; the frame's own
            right overlap v2 must be >= v2s so the last subframe's chase
            start stays inside the frame.
      start: 'boundary' | 'fixed'.
      packed: sel is (L, ceil(S/32)) int32 bit-packed words instead of
        (L, S) one-selector-per-cell (kernels/packing.py layout).

    Returns: (f,) decoded bits.
    """
    assert f % f0 == 0, "f must be a multiple of f0 (paper §IV-E alignment)"
    nsub = f // f0
    L = sel.shape[0]
    assert v1 + f + v2s <= L, "need v2 >= v2s"
    prev_state = jnp.asarray(trellis.prev_state)
    kshift = trellis.k - 2

    q = jnp.arange(nsub, dtype=jnp.int32)
    # chase start stage of subframe q (inclusive): end of kept region + v2s
    e = v1 + (q + 1) * f0 - 1 + v2s                   # (nsub,)
    if start == "boundary":
        states = amax[e].astype(jnp.int32)
    elif start == "fixed":
        states = jnp.zeros((nsub,), jnp.int32)
    else:
        raise ValueError(start)

    sel32 = sel.astype(jnp.int32)

    def step(states, r):
        t = e - r                                     # (nsub,) current stages
        bits = states >> kshift
        if packed:
            p = (sel32[t, states >> 5] >> (states & 31)) & 1
        else:
            p = sel32[t, states]                      # vectorized gather
        states = prev_state[states, p]
        return states, bits

    # chase f0 + v2s steps; the first v2s emitted bits per subframe are the
    # convergence overlap and are discarded (paper: "not stored")
    _, bits = jax.lax.scan(step, states, jnp.arange(f0 + v2s, dtype=jnp.int32))
    kept = bits[v2s:, :]                              # (f0, nsub), r-ordered
    # r = v2s + m corresponds to stage e - v2s - m = v1 + (q+1)*f0 - 1 - m:
    # reverse the step axis to get stage-ascending order within the subframe
    kept = kept[::-1, :]                              # (f0, nsub) ascending
    return kept.T.reshape((f,))                       # subframes concatenated
