"""Traceback strategies (paper §IV-D).

Two tracebacks over one frame's survivor selectors ``sel`` (L, S):

* ``serial_traceback``   — one cursor chases the whole frame (prior work).
* ``parallel_traceback`` — the frame's kept region is split into ``nsub``
  subframes of ``f0`` stages; every subframe is traced back concurrently,
  each with a right-overlap of ``v2s`` stages for survivor-path convergence
  (paper Fig. 5). Start states are either the per-stage argmax states
  recorded in the forward pass (``start='boundary'``, the paper's preferred
  solution) or a fixed state (``start='fixed'``, reproduces Fig. 11's
  degradation).

The parallel version is a *vectorized pointer chase*: all ``nsub`` cursors
advance together, so the backward pass costs f0+v2s vector steps instead of
f+v2 serial steps — the D/D' parallelism of Table I row (c).

The ``*_frames`` variants consume a whole batch of frames in one of the
two survivor-stream layouts the split kernel emits (kernels/packing.Layout):
frame-major ``lane`` streams are vmapped over frames, while Mosaic-native
``sublane`` streams (frames on the trailing lane axis) are chased directly
with the frame axis vectorized — the stream is never transposed on its way
from HBM to the decoded bits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels.packing import Layout, extract_bit, packed_width
from .trellis import Trellis

__all__ = ["serial_traceback", "parallel_traceback",
           "serial_traceback_frames", "parallel_traceback_frames"]


def serial_traceback(sel: jax.Array, trellis: Trellis, start_state: jax.Array,
                     v1: int, f: int, packed: bool = False) -> jax.Array:
    """Chase from the last stage; return the f kept bits [v1, v1+f).

    ``packed=True`` reads sel as (L, ceil(S/32)) int32 bit-packed selector
    words (kernels/packing.py layout) instead of (L, S) one-per-cell.
    """
    prev_state = jnp.asarray(trellis.prev_state)
    kshift = trellis.k - 2

    def step(j, sel_t):
        bit = j >> kshift
        if packed:
            p = (sel_t[j >> 5] >> (j & 31)) & 1
        else:
            p = sel_t[j]
        i = prev_state[j, p]
        return i, bit

    _, bits = jax.lax.scan(step, start_state.astype(jnp.int32),
                           sel.astype(jnp.int32), reverse=True)
    return jax.lax.dynamic_slice(bits, (v1,), (f,))


def parallel_traceback(sel: jax.Array, amax: jax.Array, trellis: Trellis,
                       v1: int, f: int, f0: int, v2s: int,
                       start: str = "boundary",
                       packed: bool = False) -> jax.Array:
    """Parallel traceback over ``nsub = f // f0`` subframes.

    Args:
      sel:  (L, S) selector bits from the forward pass, L >= v1 + f + v2s.
      amax: (L,) per-stage argmax states (used when start == 'boundary').
      v1/f: kept region is stages [v1, v1+f).
      f0:   subframe length (f % f0 == 0).
      v2s:  subframe right-overlap (convergence) length; the frame's own
            right overlap v2 must be >= v2s so the last subframe's chase
            start stays inside the frame.
      start: 'boundary' | 'fixed'.
      packed: sel is (L, ceil(S/32)) int32 bit-packed words instead of
        (L, S) one-selector-per-cell (kernels/packing.py layout).

    Returns: (f,) decoded bits.
    """
    assert f % f0 == 0, "f must be a multiple of f0 (paper §IV-E alignment)"
    nsub = f // f0
    L = sel.shape[0]
    assert v1 + f + v2s <= L, "need v2 >= v2s"
    prev_state = jnp.asarray(trellis.prev_state)
    kshift = trellis.k - 2

    q = jnp.arange(nsub, dtype=jnp.int32)
    # chase start stage of subframe q (inclusive): end of kept region + v2s
    e = v1 + (q + 1) * f0 - 1 + v2s                   # (nsub,)
    if start == "boundary":
        states = amax[e].astype(jnp.int32)
    elif start == "fixed":
        states = jnp.zeros((nsub,), jnp.int32)
    else:
        raise ValueError(start)

    sel32 = sel.astype(jnp.int32)

    def step(states, r):
        t = e - r                                     # (nsub,) current stages
        bits = states >> kshift
        if packed:
            p = (sel32[t, states >> 5] >> (states & 31)) & 1
        else:
            p = sel32[t, states]                      # vectorized gather
        states = prev_state[states, p]
        return states, bits

    # chase f0 + v2s steps; the first v2s emitted bits per subframe are the
    # convergence overlap and are discarded (paper: "not stored")
    _, bits = jax.lax.scan(step, states, jnp.arange(f0 + v2s, dtype=jnp.int32))
    kept = bits[v2s:, :]                              # (f0, nsub), r-ordered
    # r = v2s + m corresponds to stage e - v2s - m = v1 + (q+1)*f0 - 1 - m:
    # reverse the step axis to get stage-ascending order within the subframe
    kept = kept[::-1, :]                              # (f0, nsub) ascending
    return kept.T.reshape((f,))                       # subframes concatenated


def _sel_stages(sel: jax.Array, trellis: Trellis, packed: bool) -> jax.Array:
    """Sublane stream -> (L, W|S, F) stage-major view (packed rows are
    stored flat as (L*W, F), matching the kernels' scratch layout)."""
    if packed:
        W = packed_width(trellis.num_states)
        return sel.reshape(-1, W, sel.shape[-1])
    return sel


def serial_traceback_frames(sel: jax.Array, amax: jax.Array,
                            trellis: Trellis, v1: int, f: int,
                            packed: bool = False,
                            layout: Layout = Layout.LANE) -> jax.Array:
    """Serial traceback of a frame batch -> (F, f) bits.

    sel: lane (F, L, S|W); sublane (L*W, F) packed / (L, S, F) unpacked.
    amax: (F, L) — the chase starts from each frame's last-stage argmax.
    """
    if Layout(layout) is Layout.LANE:
        tb = lambda s, a: serial_traceback(s, trellis, a[-1], v1, f,
                                           packed=packed)
        return jax.vmap(tb)(sel, amax)
    sel3 = _sel_stages(sel.astype(jnp.int32), trellis, packed)  # (L, ., F)
    F = sel3.shape[-1]
    kshift = trellis.k - 2
    S = trellis.num_states
    states0 = amax[:, -1].astype(jnp.int32)           # (F,)

    def step(states, rows):                           # rows (W|S, F)
        bits = states >> kshift
        if packed:
            p = extract_bit(rows, states, Layout.SUBLANE)
        else:
            p = rows[states, jnp.arange(F)]
        return ((states << 1) & (S - 1)) | p, bits    # butterfly arithmetic

    _, bits = jax.lax.scan(step, states0, sel3, reverse=True)  # (L, F)
    return jax.lax.dynamic_slice(bits, (v1, 0), (f, F)).T


def parallel_traceback_frames(sel: jax.Array, amax: jax.Array,
                              trellis: Trellis, v1: int, f: int, f0: int,
                              v2s: int, start: str = "boundary",
                              packed: bool = False,
                              layout: Layout = Layout.LANE) -> jax.Array:
    """Parallel traceback of a frame batch -> (F, f) bits.

    sel: lane (F, L, S|W); sublane (L*W, F) packed / (L, S, F) unpacked.
    amax: (F, L). In the sublane layout all nsub cursors of all F frames
    advance in lock-step with frames on the trailing (lane) axis — the
    JAX-level mirror of the unified kernel's phase 3.
    """
    if Layout(layout) is Layout.LANE:
        tb = lambda s, a: parallel_traceback(s, a, trellis, v1, f, f0, v2s,
                                             start, packed=packed)
        return jax.vmap(tb)(sel, amax)
    assert f % f0 == 0, "f must be a multiple of f0 (paper §IV-E alignment)"
    nsub = f // f0
    sel3 = _sel_stages(sel.astype(jnp.int32), trellis, packed)  # (L, ., F)
    F = sel3.shape[-1]
    kshift = trellis.k - 2
    S = trellis.num_states

    q = jnp.arange(nsub, dtype=jnp.int32)
    e = v1 + (q + 1) * f0 - 1 + v2s                   # (nsub,)
    if start == "boundary":
        states = jnp.take(amax, e, axis=1).T.astype(jnp.int32)  # (nsub, F)
    elif start == "fixed":
        states = jnp.zeros((nsub, F), jnp.int32)
    else:
        raise ValueError(start)

    def step(states, r):
        rows = jnp.take(sel3, e - r, axis=0)          # (nsub, W|S, F)
        bits = states >> kshift
        if packed:
            p = extract_bit(rows, states, Layout.SUBLANE)
        else:
            onehot = (states[:, None, :]
                      == jnp.arange(S, dtype=jnp.int32)[None, :, None])
            p = jnp.sum(rows * onehot.astype(jnp.int32), axis=1)
        return ((states << 1) & (S - 1)) | p, bits

    _, bits = jax.lax.scan(step, states,
                           jnp.arange(f0 + v2s, dtype=jnp.int32))
    kept = bits[v2s:][::-1]                           # (f0, nsub, F) ascending
    return jnp.transpose(kept, (2, 1, 0)).reshape(F, f)
