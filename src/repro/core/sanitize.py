"""LLR input hardening: NaN/Inf scrub and out-of-range clamp.

A decode service fed by real demodulators sees poisoned buffers: NaN/Inf
from upstream DSP bugs, and absurd magnitudes from AGC glitches. A single
NaN is not locally contained — it propagates through the ACS max into
every path metric of its frame (NaN poisons ``max`` comparisons), turning
one bad sample into a garbage frame; ±Inf saturates the metrics and
``inf - inf = NaN`` in the per-stage normalization does the same. The fix
is cheap and information-theoretically sound: a non-finite soft symbol
carries no information, so it becomes the neutral zero LLR — exactly how
depuncturing treats erased symbols (paper §IV-E) — and finite outliers
clamp to ``±clip``, preserving their sign (the hard decision) while
bounding the metric growth fp32/bf16 must absorb.

``sanitize_llr`` is the host-side boundary filter used by the stream and
serve push paths; ``make_decoder`` applies the same rule in-graph. Both
are BIT-IDENTICAL on clean inputs: values that are finite and within
``±clip`` pass through untouched (the host path returns the input array
itself when nothing needs fixing).
"""
from __future__ import annotations

import numpy as np

__all__ = ["LLR_CLIP", "sanitize_llr"]

#: Default magnitude clamp. Far beyond any sane LLR (|llr| ~ tens at the
#: SNRs where decoding is meaningful) yet small enough that a whole decode
#: window of clamped symbols stays orders of magnitude inside fp32 range
#: even with per-stage renormalization disabled.
LLR_CLIP = 1e6


def sanitize_llr(llr, clip: float = LLR_CLIP,
                 policy: str = "zero") -> tuple[np.ndarray, int]:
    """Scrub an LLR buffer; returns ``(clean, n_bad)``.

    policy='zero'  : NaN/Inf -> 0.0 (neutral erasure), |x| > clip ->
                     ±clip. Returns the INPUT array untouched when
                     n_bad == 0 — the clean path is bit-identical and
                     copy-free.
    policy='raise' : raise ValueError on the first poisoned buffer
                     (strict tenants who prefer rejection to erasure).
    policy='off'   : no scan at all; returns (asarray(llr), 0).
    """
    arr = np.asarray(llr, np.float32)
    if policy == "off":
        return arr, 0
    if policy not in ("zero", "raise"):
        raise ValueError(f"sanitize policy must be 'zero', 'raise' or "
                         f"'off', got {policy!r}")
    finite = np.isfinite(arr)
    bad = ~finite | (np.abs(arr) > clip)
    n_bad = int(bad.sum())
    if n_bad == 0:
        return arr, 0
    if policy == "raise":
        raise ValueError(
            f"{n_bad} non-finite or out-of-range (|llr| > {clip:g}) "
            f"values in a push of {arr.size}")
    out = np.where(finite, np.clip(arr, -clip, clip), np.float32(0.0))
    return out.astype(np.float32, copy=False), n_bad
