"""Zero-dependency tracing & metrics for the decode pipeline.

Where a window's latency goes — queue wait vs batch pack vs kernel
launch vs retire — and what the planner/plan-cache actually decided, as
(1) nestable spans with structured attributes (``tracer``), (2) fixed-
bucket latency/size histograms (``hist``), and (3) exportable artifacts:
Chrome trace-event JSON for Perfetto and a Prometheus text exposition
(``export``).

Enable for a whole process with one call (everything that resolved
``trace=None`` through :func:`get_tracer` lights up)::

    from repro.obs import Tracer, set_tracer, write_chrome_trace
    tracer = Tracer()
    set_tracer(tracer)
    ... run the server / stream ...
    write_chrome_trace(tracer, "trace.json")   # open in Perfetto

or pass ``trace=tracer`` to ``DecodeServer`` / ``StreamDecoder``
explicitly. Disabled (the default) the whole layer is a shared no-op
object — nothing allocates on the hot path.
"""
from .tracer import (Tracer, NullTracer, NULL_TRACER,      # noqa: F401
                     SpanRecord, get_tracer, set_tracer)
from .hist import (Histogram, geometric_bounds,            # noqa: F401
                   LATENCY_MS_BOUNDS, SIZE_BOUNDS)
from .export import (chrome_trace, write_chrome_trace,     # noqa: F401
                     prometheus_text, write_metrics_json)

__all__ = ["Tracer", "NullTracer", "NULL_TRACER", "SpanRecord",
           "get_tracer", "set_tracer", "Histogram", "geometric_bounds",
           "LATENCY_MS_BOUNDS", "SIZE_BOUNDS", "chrome_trace",
           "write_chrome_trace", "prometheus_text", "write_metrics_json"]
