"""Fixed-bucket histograms: O(1) record, O(buckets) percentiles.

The serve metrics used to keep a 4096-sample deque per bucket and
re-concatenate every sample on each ``totals()`` call — O(all samples)
per snapshot, and a hard cap on how much history a percentile can see.
A fixed-bucket histogram inverts the trade: recording is one bisect into
a static bound table, snapshots walk the (constant) bucket array, memory
is O(buckets) forever, and two histograms merge by adding counts — which
is exactly what ``ServeMetrics.totals()`` needs to aggregate buckets.

Percentiles are interpolated inside the containing bucket and clamped to
the observed [min, max], so they are exact for degenerate distributions
(one repeated value) and within one bucket's resolution otherwise. The
default latency bounds are geometric with ratio 2**0.25 (~19% per step)
from 1 ns to 100 s, so any latency percentile is within ~19% of the
exact sample percentile — tests/test_obs.py gates this against
``np.percentile``.

Pure stdlib (the obs layer is zero-dependency by design).
"""
from __future__ import annotations

import bisect

__all__ = ["Histogram", "geometric_bounds", "LATENCY_MS_BOUNDS",
           "SIZE_BOUNDS"]


def geometric_bounds(lo: float, hi: float, ratio: float) -> tuple:
    """Increasing bucket upper-edges ``lo, lo*ratio, ...`` up past ``hi``."""
    assert lo > 0 and ratio > 1 and hi > lo
    out = [lo]
    while out[-1] < hi:
        out.append(out[-1] * ratio)
    return tuple(out)


#: Latency bounds (milliseconds): 1e-3 ms .. 1e5 ms, ~19%/bucket.
LATENCY_MS_BOUNDS = geometric_bounds(1e-3, 1e5, 2 ** 0.25)

#: Size bounds (counts — frames, bits, bytes): powers of two to 2**30.
SIZE_BOUNDS = tuple(float(1 << i) for i in range(31))


class Histogram:
    """Fixed-bucket scalar histogram.

    ``bounds`` are increasing bucket *upper* edges; bucket i holds values
    in (bounds[i-1], bounds[i]] (bucket 0: [0, bounds[0]]), plus one
    overflow bucket past the last edge. All histograms built from the
    same bounds can ``merge``.
    """

    __slots__ = ("bounds", "counts", "count", "total", "vmin", "vmax")

    def __init__(self, bounds=LATENCY_MS_BOUNDS):
        bounds = tuple(float(b) for b in bounds)
        assert bounds and all(a < b for a, b in zip(bounds, bounds[1:]))
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")

    @classmethod
    def latency_ms(cls) -> "Histogram":
        return cls(LATENCY_MS_BOUNDS)

    @classmethod
    def sizes(cls) -> "Histogram":
        return cls(SIZE_BOUNDS)

    def record(self, x) -> None:
        x = float(x)
        self.counts[bisect.bisect_left(self.bounds, x)] += 1
        self.count += 1
        self.total += x
        if x < self.vmin:
            self.vmin = x
        if x > self.vmax:
            self.vmax = x

    def extend(self, xs) -> None:
        for x in xs:
            self.record(x)

    def merge(self, other: "Histogram") -> "Histogram":
        """Fold ``other`` into self (same bounds required); returns self."""
        if other.bounds != self.bounds:
            raise ValueError("cannot merge histograms with different bounds")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.count += other.count
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)
        return self

    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, p: float) -> float:
        """p-th percentile (0.0 when empty): linear interpolation inside
        the containing bucket, clamped to the observed [min, max]."""
        if not self.count:
            return 0.0
        target = max(1e-12, (p / 100.0) * self.count)
        cum = 0
        for i, c in enumerate(self.counts):
            if c and cum + c >= target:
                lo = self.bounds[i - 1] if i > 0 else 0.0
                hi = self.bounds[i] if i < len(self.bounds) else self.vmax
                frac = (target - cum) / c
                val = lo + frac * max(0.0, hi - lo)
                return min(max(val, self.vmin), self.vmax)
            cum += c
        return self.vmax

    def cumulative(self) -> list:
        """``(upper_bound, cumulative_count)`` per bucket, ending with
        ``(inf, count)`` — the Prometheus histogram exposition shape
        (``_bucket{le=...}`` samples are cumulative and always include
        the ``+Inf`` bucket)."""
        out, cum = [], 0
        for bound, c in zip(self.bounds, self.counts):
            cum += c
            out.append((bound, cum))
        out.append((float("inf"), self.count))
        return out

    def snapshot(self) -> dict:
        """JSON-ready summary (keys shared by the stage-latency rows in
        ``metrics_snapshot()`` and the Prometheus exposition)."""
        return {"count": self.count, "total": round(self.total, 3),
                "mean": round(self.mean(), 4),
                "p50": round(self.percentile(50), 4),
                "p99": round(self.percentile(99), 4),
                "max": round(self.vmax, 4) if self.count else 0.0}

    def state_dict(self) -> dict:
        """Full JSON-ready state (counts included), for the serve
        checkpoint: a restored histogram keeps reporting the same
        percentiles the pre-crash server did. ``vmin``/``vmax`` are None
        while empty (JSON has no +-inf)."""
        return {"counts": list(self.counts), "count": self.count,
                "total": self.total,
                "vmin": self.vmin if self.count else None,
                "vmax": self.vmax if self.count else None}

    def load_state(self, state: dict) -> "Histogram":
        """Restore a ``state_dict`` into this histogram (whose bounds
        must have the same bucket count); returns self."""
        counts = list(state["counts"])
        if len(counts) != len(self.counts):
            raise ValueError(
                f"histogram state has {len(counts)} buckets, this "
                f"histogram has {len(self.counts)}")
        self.counts = [int(c) for c in counts]
        self.count = int(state["count"])
        self.total = float(state["total"])
        self.vmin = float("inf") if state["vmin"] is None \
            else float(state["vmin"])
        self.vmax = float("-inf") if state["vmax"] is None \
            else float(state["vmax"])
        return self
