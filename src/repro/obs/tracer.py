"""Nestable spans + counters: the zero-dependency tracing core.

A ``Tracer`` records *spans* — named intervals with monotonic start time,
duration, and structured attributes — into a bounded, thread-safe ring
buffer. Three span flavors map onto the three shapes of work in the
decode pipeline:

  * ``span(name, **attrs)`` — a context manager for synchronous work
    (a push, a batched launch, a retire). Spans nest: the record carries
    its enclosing span's name, tracked per thread, so an exported trace
    shows ``launch_attempt`` inside ``launch`` inside a serve step.
  * ``begin(name, **attrs)`` / ``handle.end(**attrs)`` — an *async* span
    for work that overlaps other work (a dispatched chunk in flight
    behind the double-buffer front). Async spans may overlap freely;
    the Chrome exporter emits them as b/e pairs so Perfetto draws the
    overlap instead of faking a nesting.
  * ``event(name, **attrs)`` — an instant (a retry, a trace-time kernel
    specialization).

``count(name, n)`` bumps a named counter (plan-cache hits, kernel
traces); counters ride along in the exported trace metadata.

The pay-nothing contract (same as ``faults=`` in the serve layer): the
process-global tracer defaults to ``NULL_TRACER``, whose ``span``/
``begin`` return one shared no-op object and whose ``event``/``count``
are empty methods — no allocation, no lock, no branch beyond the call
itself. Components resolve ``trace=None`` to ``get_tracer()`` at
construction, so enabling observability is one ``set_tracer(Tracer())``
call and disabling it costs nothing on the hot path.

Storage is a ``deque(maxlen=capacity)`` ring: a long-running server keeps
O(capacity) memory and the trace describes recent traffic, exactly like
the serve metrics' rolling latency window.
"""
from __future__ import annotations

import collections
import itertools
import threading
import time

__all__ = ["SpanRecord", "Tracer", "NullTracer", "NULL_TRACER",
           "get_tracer", "set_tracer"]

#: Completed spans retained (ring buffer) by default.
DEFAULT_CAPACITY = 65536


class SpanRecord:
    """One completed span (or instant event). ``ts``/``dur`` are
    ``time.perf_counter`` seconds; the exporter rebases onto the tracer's
    epoch. ``kind`` is 'span' (sync, nests via ``parent``), 'async'
    (overlapping, pairs via ``sid``), or 'instant'."""
    __slots__ = ("name", "ts", "dur", "tid", "parent", "attrs", "kind",
                 "sid")

    def __init__(self, name, ts, dur, tid, parent, attrs, kind, sid=0):
        self.name = name
        self.ts = ts
        self.dur = dur
        self.tid = tid
        self.parent = parent
        self.attrs = attrs
        self.kind = kind
        self.sid = sid

    def __repr__(self):
        return (f"SpanRecord({self.name!r}, dur={self.dur * 1e3:.3f}ms, "
                f"kind={self.kind}, parent={self.parent!r})")


class _Span:
    """Sync span context manager (one per ``Tracer.span`` call)."""
    __slots__ = ("_tr", "name", "attrs", "_t0", "_parent")

    def __init__(self, tracer, name, attrs):
        self._tr = tracer
        self.name = name
        self.attrs = attrs

    def set(self, **attrs):
        """Attach attributes mid-span (e.g. the plan a planner chose)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self):
        stack = self._tr._stack()
        self._parent = stack[-1].name if stack else None
        stack.append(self)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        t1 = time.perf_counter()
        stack = self._tr._stack()
        if stack and stack[-1] is self:
            stack.pop()
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)
        self._tr._record(SpanRecord(
            self.name, self._t0, t1 - self._t0, threading.get_ident(),
            self._parent, self.attrs, "span"))
        return False


class _AsyncSpan:
    """Handle returned by ``Tracer.begin``; call ``end()`` when the
    overlapped work materializes. Safe to end at most once."""
    __slots__ = ("_tr", "name", "attrs", "_t0", "_sid", "_done")

    def __init__(self, tracer, name, attrs, sid):
        self._tr = tracer
        self.name = name
        self.attrs = attrs
        self._t0 = time.perf_counter()
        self._sid = sid
        self._done = False

    def set(self, **attrs):
        self.attrs.update(attrs)
        return self

    def end(self, **attrs):
        if self._done:
            return
        self._done = True
        if attrs:
            self.attrs.update(attrs)
        t1 = time.perf_counter()
        self._tr._record(SpanRecord(
            self.name, self._t0, t1 - self._t0, threading.get_ident(),
            None, self.attrs, "async", self._sid))


class Tracer:
    """Thread-safe span/counter recorder with ring-buffer storage."""

    enabled = True

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        assert capacity > 0
        self._lock = threading.Lock()
        self._spans: collections.deque = collections.deque(maxlen=capacity)
        self._counters = collections.Counter()
        self._tls = threading.local()
        self._ids = itertools.count(1)
        self.t0 = time.perf_counter()           # export epoch

    # -- recording --------------------------------------------------------
    def _stack(self) -> list:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def _record(self, rec: SpanRecord) -> None:
        with self._lock:
            self._spans.append(rec)

    def span(self, name: str, **attrs) -> _Span:
        """Context manager: records a sync span on exit, nested under the
        thread's currently-open span."""
        return _Span(self, name, attrs)

    def begin(self, name: str, **attrs) -> _AsyncSpan:
        """Open an async (overlapping) span; ``.end()`` completes it."""
        return _AsyncSpan(self, name, attrs, next(self._ids))

    def event(self, name: str, **attrs) -> None:
        """Record an instant event (zero duration)."""
        t = time.perf_counter()
        stack = self._stack()
        self._record(SpanRecord(name, t, 0.0, threading.get_ident(),
                                stack[-1].name if stack else None, attrs,
                                "instant"))

    def count(self, name: str, n: int = 1) -> None:
        """Bump a named counter."""
        with self._lock:
            self._counters[name] += n

    # -- introspection ----------------------------------------------------
    def spans(self) -> list:
        """Snapshot of the retained span records (oldest first)."""
        with self._lock:
            return list(self._spans)

    def counters(self) -> dict:
        with self._lock:
            return dict(self._counters)

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self._counters.clear()


class _NullSpan:
    """The shared no-op span/handle: enter/exit/set/end all do nothing.
    One instance serves every disabled call site — the disabled hot path
    allocates nothing."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False

    def set(self, **attrs):
        return self

    def end(self, **attrs):
        return None


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Disabled tracer: every hook is a no-op returning shared objects."""

    enabled = False
    t0 = 0.0

    def span(self, name: str, **attrs) -> _NullSpan:
        return _NULL_SPAN

    def begin(self, name: str, **attrs) -> _NullSpan:
        return _NULL_SPAN

    def event(self, name: str, **attrs) -> None:
        return None

    def count(self, name: str, n: int = 1) -> None:
        return None

    def spans(self) -> list:
        return []

    def counters(self) -> dict:
        return {}

    def clear(self) -> None:
        return None


#: The shared disabled tracer (the ``trace=None`` resolution target).
NULL_TRACER = NullTracer()

_global_tracer = NULL_TRACER
_global_lock = threading.Lock()


def get_tracer():
    """The process-global tracer (``NULL_TRACER`` unless one was set).
    Components resolve ``trace=None`` through this at construction, and
    trace-time hooks (kernel wrapper, planner, plan cache) consult it
    directly — one ``set_tracer`` lights up the whole pipeline."""
    return _global_tracer


def set_tracer(tracer):
    """Install ``tracer`` as the process-global tracer (``None`` restores
    ``NULL_TRACER``). Returns the previous tracer so callers can scope an
    enablement and restore it."""
    global _global_tracer
    with _global_lock:
        prev = _global_tracer
        _global_tracer = tracer if tracer is not None else NULL_TRACER
        return prev
