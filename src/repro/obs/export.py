"""Exporters: Chrome ``trace_event`` JSON and metrics expositions.

Two consumers, two formats:

  * ``chrome_trace`` / ``write_chrome_trace`` — the tracer's span ring as
    a Chrome trace-event JSON object, loadable in Perfetto or
    chrome://tracing. Sync spans become complete ('X') events nested by
    thread, async spans (double-buffered chunks/launches in flight)
    become b/e pairs so their overlap renders as overlap, instants
    become 'i' events, and the tracer's counters ride in ``otherData``.
  * ``prometheus_text`` — a ``DecodeServer.metrics_snapshot()`` dict as
    Prometheus text exposition (``# TYPE`` lines + ``name{labels} value``
    samples), scrapable as-is; ``write_metrics_json`` is the same
    snapshot as a JSON file for offline diffing.

Pure stdlib; nothing here imports the decode stack, so the obs layer
stays dependency-free in both directions.
"""
from __future__ import annotations

import json
import re

__all__ = ["chrome_trace", "write_chrome_trace", "prometheus_text",
           "write_metrics_json"]

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")

#: Snapshot fields exposed as monotone counters (everything else is a
#: gauge). Mirrors serve.metrics.FAULT_COUNTERS plus the volume fields —
#: kept local so obs never imports the decode stack.
_COUNTER_KEYS = frozenset({
    "launches", "windows", "frames", "pad_frames", "bits",
    "launch_errors", "timeouts", "retries", "degraded", "cache_refreshes",
    "poisoned_pushes", "sanitized_values", "quarantined",
    "entries", "hits", "misses", "traces"})


def _jsonable(v):
    """Attribute values must survive json.dump: pass scalars through,
    stringify everything else (enums, tuples, arrays)."""
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    return str(v)


def chrome_trace(tracer) -> dict:
    """The tracer's retained spans as a Chrome trace-event object.

    Timestamps are microseconds since the tracer's epoch (``tracer.t0``),
    everything on one pid with one tid per recording thread.
    """
    events = [{"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
               "args": {"name": "repro-viterbi-decode"}}]
    tids: dict = {}
    epoch = getattr(tracer, "t0", 0.0)
    for rec in tracer.spans():
        tid = tids.setdefault(rec.tid, len(tids))
        ts = (rec.ts - epoch) * 1e6
        args = {k: _jsonable(v) for k, v in rec.attrs.items()}
        if rec.parent is not None:
            args.setdefault("parent", rec.parent)
        base = {"name": rec.name, "cat": "decode", "pid": 0, "tid": tid,
                "args": args}
        if rec.kind == "span":
            events.append({**base, "ph": "X", "ts": round(ts, 3),
                           "dur": round(rec.dur * 1e6, 3)})
        elif rec.kind == "instant":
            events.append({**base, "ph": "i", "ts": round(ts, 3), "s": "t"})
        else:                                   # async: overlap as b/e pair
            ident = str(rec.sid)
            events.append({**base, "cat": "async", "ph": "b",
                           "id": ident, "ts": round(ts, 3)})
            events.append({"name": rec.name, "cat": "async", "ph": "e",
                           "id": ident, "pid": 0, "tid": tid, "args": {},
                           "ts": round(ts + rec.dur * 1e6, 3)})
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"counters": tracer.counters()}}


def write_chrome_trace(tracer, path: str) -> dict:
    """Dump ``chrome_trace(tracer)`` to ``path``; returns the object."""
    obj = chrome_trace(tracer)
    with open(path, "w") as fh:
        json.dump(obj, fh)
        fh.write("\n")
    return obj


def _metric_name(*parts: str) -> str:
    return _NAME_RE.sub("_", "_".join(p for p in parts if p))


def _label(value) -> str:
    return str(value).replace("\\", r"\\").replace('"', r'\"')


def _labelstr(labels: dict | None) -> str:
    if not labels:
        return ""
    return ("{" + ",".join(f'{k}="{_label(v)}"'
                           for k, v in sorted(labels.items())) + "}")


class _Expo:
    """Accumulates exposition lines with one # TYPE header per metric."""

    def __init__(self):
        self.lines: list[str] = []
        self._typed: set[str] = set()

    def sample(self, name: str, value, labels: dict | None = None,
               mtype: str = "gauge"):
        if not isinstance(value, (int, float)) or isinstance(value, bool):
            return
        if name not in self._typed:
            self._typed.add(name)
            self.lines.append(f"# TYPE {name} {mtype}")
        self.lines.append(f"{name}{_labelstr(labels)} {value}")

    def histogram(self, name: str, buckets, total, count,
                  labels: dict | None = None):
        """One Prometheus histogram: ``# TYPE name histogram`` once, then
        ``name_bucket{le=...}`` samples (cumulative, ending at +Inf) plus
        ``name_sum``/``name_count`` — the convention every Prometheus
        aggregator understands (histogram_quantile works on these)."""
        if name not in self._typed:
            self._typed.add(name)
            self.lines.append(f"# TYPE {name} histogram")
        labels = labels or {}
        for le, c in buckets:
            self.lines.append(
                f"{name}_bucket{_labelstr({**labels, 'le': le})} {c}")
        self.lines.append(f"{name}_sum{_labelstr(labels)} {total}")
        self.lines.append(f"{name}_count{_labelstr(labels)} {count}")


def prometheus_text(snapshot: dict, prefix: str = "repro_serve") -> str:
    """A ``metrics_snapshot()`` dict as Prometheus text exposition.

    Emits totals (counters + gauges), per-bucket rows with a
    ``bucket=...`` label, stage-latency summaries with ``stage=...`` and
    ``stat=...`` labels, the server-wide stage histograms as true
    Prometheus histogram series (``{prefix}_stage_ms_bucket{stage=,le=}``
    cumulative samples + ``_sum``/``_count``, from the snapshot's
    ``stages_hist`` key), and the plan-cache counters
    (entries/hits/misses/traces/build_ms). Non-numeric fields (health
    strings, error messages) are skipped — expositions carry numbers
    only; the histogram ``le`` bound rides in a label so the ``+Inf``
    bucket stays exposition-legal.
    """
    expo = _Expo()
    for key, val in sorted(snapshot.get("totals", {}).items()):
        mtype = "counter" if key in _COUNTER_KEYS else "gauge"
        expo.sample(_metric_name(prefix, key), val, mtype=mtype)
    for scalar in ("sessions", "quarantined_sessions"):
        if scalar in snapshot:
            expo.sample(_metric_name(prefix, scalar), snapshot[scalar])
    for row in snapshot.get("buckets", []):
        labels = {"bucket": row.get("bucket", "?")}
        for key, val in sorted(row.items()):
            if key == "bucket":
                continue
            mtype = "counter" if key in _COUNTER_KEYS else "gauge"
            expo.sample(_metric_name(prefix, "bucket", key), val, labels,
                        mtype)
    for stage, summ in sorted(snapshot.get("stages", {}).items()):
        name = _metric_name(prefix, "stage", "latency_ms")
        for stat, val in sorted(summ.items()):
            expo.sample(name, val, {"stage": stage, "stat": stat})
    # full-resolution stage histograms (snapshot "stages_hist"): real
    # Prometheus histogram series — unlike the p50/p99 gauges above these
    # aggregate across servers, so a fleet dashboard can compute honest
    # fleet-wide quantiles with histogram_quantile()
    for stage, hist in sorted(snapshot.get("stages_hist", {}).items()):
        expo.histogram(_metric_name(prefix, "stage", "ms"),
                       hist.get("buckets", ()), hist.get("sum", 0),
                       hist.get("count", 0), {"stage": stage})
    for key, val in sorted(snapshot.get("plan_cache", {}).items()):
        mtype = "counter" if key in _COUNTER_KEYS else "gauge"
        expo.sample(_metric_name(prefix, "plan_cache", key), val,
                    mtype=mtype)
    return "\n".join(expo.lines) + "\n"


def write_metrics_json(snapshot: dict, path: str) -> None:
    """The snapshot as pretty JSON (the offline twin of the exposition)."""
    with open(path, "w") as fh:
        json.dump(snapshot, fh, indent=1, sort_keys=True, default=str)
        fh.write("\n")
