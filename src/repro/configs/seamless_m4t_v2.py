"""seamless-m4t-large-v2 [audio] — encoder-decoder, multimodal.

24L enc + 24L dec, d_model=1024 16H (kv=16) d_ff=8192 vocab=256206 (padded
256256). The speech frontend is a STUB per spec: input_specs() provides
precomputed frame embeddings (B, S, d_model) to the encoder.
[arXiv:2308.11596]
"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2", family="encdec", num_layers=24,
        enc_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
        d_ff=8192, vocab=256206, audio_frontend=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="seamless-reduced", family="encdec", num_layers=2, enc_layers=2,
        d_model=64, num_heads=4, num_kv_heads=4, d_ff=128, vocab=333,
        vocab_round=8, audio_frontend=True,
    )
