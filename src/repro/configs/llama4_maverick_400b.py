"""llama4-maverick-400b-a17b [moe] — interleaved MoE + shared expert.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, 128 experts top-1.
Maverick interleaves MoE every other layer (dense d_ff elsewhere) and runs a
shared expert in parallel with the routed one. [hf:meta-llama/Llama-4-*]
"""
from .base import ModelConfig, MoESpec


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b", family="moe", num_layers=48,
        d_model=5120, num_heads=40, num_kv_heads=8, d_ff=8192, vocab=202048,
        rope_theta=5e5,
        moe=MoESpec(num_experts=128, top_k=1, d_ff_expert=8192, period=2,
                    shared_expert=True),
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama4-reduced", family="moe", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab=307, vocab_round=8,
        moe=MoESpec(num_experts=4, top_k=1, d_ff_expert=128, period=2,
                    shared_expert=True, group_size=16),
    )
