"""qwen3-32b [dense] — qk_norm + GQA. 64L d_model=5120 64H (kv=8)
d_ff=25600 vocab=151936. [hf:Qwen/Qwen3-*]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b", family="dense", num_layers=64, d_model=5120,
        num_heads=64, num_kv_heads=8, d_ff=25600, vocab=151936,
        head_dim=128, qk_norm=True, rope_theta=1e6,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-reduced", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=160, vocab=211, head_dim=16,
        qk_norm=True, vocab_round=8,
    )
