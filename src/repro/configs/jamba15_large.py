"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e
top-2 every other layer. 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536. [arXiv:2403.19887]

Layer pattern (period 8, tiled 9x = 72 layers): attention at position 4,
Mamba elsewhere; MoE replaces the dense FF on every other layer. Each layer
is (mixer, FF) like the Jamba paper. Our SSD block stands in for Jamba's
Mamba-1 mixer (same state size; DESIGN.md §6).
"""
from .base import ModelConfig, MoESpec, SSMSpec


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b", family="hybrid", num_layers=72,
        d_model=8192, num_heads=64, num_kv_heads=8, d_ff=24576, vocab=65536,
        block_pattern=("M", "M", "M", "M", "A", "M", "M", "M"),
        moe=MoESpec(num_experts=16, top_k=2, d_ff_expert=24576, period=2),
        ssm=SSMSpec(d_state=128, headdim=128, expand=2, ngroups=8,
                    d_conv=4, chunk=256),
        sub_quadratic=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="jamba-reduced", family="hybrid", num_layers=4, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab=211, vocab_round=8,
        block_pattern=("M", "A"),
        moe=MoESpec(num_experts=4, top_k=2, d_ff_expert=128, period=2,
                    group_size=16),
        ssm=SSMSpec(d_state=16, headdim=16, expand=2, ngroups=2,
                    d_conv=4, chunk=8),
        sub_quadratic=True,
    )
