"""qwen1.5-32b [dense] — QKV bias, MHA-heavy GQA (kv=40). 64L d_model=5120
40H d_ff=27392 vocab=152064. [hf:Qwen/Qwen1.5-*]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b", family="dense", num_layers=64, d_model=5120,
        num_heads=40, num_kv_heads=40, d_ff=27392, vocab=152064,
        qkv_bias=True, rope_theta=1e6,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen15-reduced", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=128, vocab=211, vocab_round=8,
        qkv_bias=True,
    )
