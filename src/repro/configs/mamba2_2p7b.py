"""mamba2-2.7b [ssm] — SSD (state-space duality), attention-free.

64L d_model=2560 (d_ff=0: the Mamba-2 block contains its own gated MLP
capacity via expand=2), vocab 50280 (padded to 50432), ssm_state=128.
[arXiv:2405.21060]
"""
from .base import ModelConfig, SSMSpec


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b", family="ssm", num_layers=64, d_model=2560,
        num_heads=80, num_kv_heads=80, d_ff=0, vocab=50280,
        ssm=SSMSpec(d_state=128, headdim=64, expand=2, ngroups=1,
                    d_conv=4, chunk=256),
        block_pattern=("M",), sub_quadratic=True, tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mamba2-reduced", family="ssm", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=0, vocab=503,
        ssm=SSMSpec(d_state=16, headdim=16, expand=2, ngroups=1,
                    d_conv=4, chunk=8),
        block_pattern=("M",), sub_quadratic=True, tie_embeddings=True,
        vocab_round=8,
    )
