"""starcoder2-7b [dense] — GQA + RoPE. 32L d_model=4608 36H (kv=4)
d_ff=18432 vocab=49152. [arXiv:2402.19173]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b", family="dense", num_layers=32, d_model=4608,
        num_heads=36, num_kv_heads=4, d_ff=18432, vocab=49152,
        qkv_bias=True, rope_theta=1e5,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-reduced", family="dense", num_layers=2, d_model=72,
        num_heads=6, num_kv_heads=2, d_ff=144, vocab=193, vocab_round=8,
        qkv_bias=True,
    )
