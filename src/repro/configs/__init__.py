from .base import (ModelConfig, MoESpec, SSMSpec, ShapeSpec, SHAPES,
                   get_config, ARCH_IDS)  # noqa: F401
