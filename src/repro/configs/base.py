"""Model/config dataclasses + registry for the assigned architectures."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

import jax.numpy as jnp

__all__ = ["MoESpec", "SSMSpec", "ModelConfig", "ShapeSpec", "SHAPES",
           "get_config", "ARCH_IDS"]


@dataclasses.dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    d_ff_expert: int
    period: int = 1            # MoE every `period`-th layer (others dense)
    shared_expert: bool = False  # parallel dense expert (llama4-style)
    capacity_per_choice: float = 2.0   # per-top-1-slice capacity factor
    group_size: int = 512      # routing group (dispatch memory knob)


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    d_state: int = 128
    headdim: int = 64
    expand: int = 2
    ngroups: int = 1
    d_conv: int = 4
    chunk: int = 256           # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                # dense|moe|ssm|hybrid|encdec|vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0          # 0 -> d_model // num_heads
    rope_theta: float = 1e4
    qk_norm: bool = False
    qkv_bias: bool = False
    moe: Optional[MoESpec] = None
    ssm: Optional[SSMSpec] = None
    # layer pattern, tiled to num_layers: 'A' = attention, 'M' = mamba
    block_pattern: tuple = ("A",)
    enc_layers: int = 0        # >0 -> encoder-decoder (num_layers = decoder)
    vision_patches: int = 0    # >0 -> early-fusion patch-embedding stub
    audio_frontend: bool = False   # encoder input is precomputed frames
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    vocab_round: int = 256     # pad vocab to a multiple (mesh divisibility)
    tie_embeddings: bool = False
    attn_chunk: int = 1024     # blockwise-attention q/kv chunk (flash-style)
    sub_quadratic: bool = False  # supports long_500k (SSM/hybrid)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        r = self.vocab_round
        return -(-self.vocab // r) * r

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pattern(self) -> tuple:
        reps = -(-self.num_layers // len(self.block_pattern))
        return (self.block_pattern * reps)[: self.num_layers]

    def moe_at(self, layer_idx: int) -> bool:
        return self.moe is not None and (layer_idx % self.moe.period
                                         == self.moe.period - 1)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k":    ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeSpec("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "mamba2_2p7b", "phi3_vision_4p2b", "llama4_maverick_400b",
    "qwen3_moe_235b", "internlm2_20b", "starcoder2_7b", "qwen3_32b",
    "qwen15_32b", "seamless_m4t_v2", "jamba15_large",
]


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    """Load ``src/repro/configs/<arch>.py`` and return its config."""
    arch = arch.replace("-", "_").replace(".", "p")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.reduced() if reduced else mod.config()
