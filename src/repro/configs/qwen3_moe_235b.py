"""qwen3-moe-235b-a22b [moe] — 128 experts top-8, every layer.

94L d_model=4096 64H (GQA kv=4) moe d_ff=1536 vocab=151936, qk_norm.
[hf:Qwen/Qwen3-*]
"""
from .base import ModelConfig, MoESpec


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b", family="moe", num_layers=94,
        d_model=4096, num_heads=64, num_kv_heads=4, d_ff=1536, vocab=151936,
        head_dim=128, qk_norm=True, rope_theta=1e6,
        moe=MoESpec(num_experts=128, top_k=8, d_ff_expert=1536, period=1),
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3moe-reduced", family="moe", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=48, vocab=211, head_dim=16,
        qk_norm=True, vocab_round=8,
        moe=MoESpec(num_experts=4, top_k=2, d_ff_expert=48, period=1,
                    group_size=16),
    )
