"""internlm2-20b [dense] — GQA. 48L d_model=6144 48H (kv=8) d_ff=16384
vocab=92544. [arXiv:2403.17297]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b", family="dense", num_layers=48, d_model=6144,
        num_heads=48, num_kv_heads=8, d_ff=16384, vocab=92544,
        rope_theta=1e6,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="internlm2-reduced", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab=157, vocab_round=8,
    )
