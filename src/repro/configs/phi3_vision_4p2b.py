"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP frontend (stub).

32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064. The vision frontend is
a STUB per spec: input_specs() provides precomputed patch embeddings
(B, P, d_model) fused early with the token embeddings.
[hf:microsoft/Phi-3-vision-128k-instruct]
"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b", family="vlm", num_layers=32, d_model=3072,
        num_heads=32, num_kv_heads=32, d_ff=8192, vocab=32064,
        vision_patches=256,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="phi3v-reduced", family="vlm", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=128, vocab=211,
        vision_patches=8, vocab_round=8,
    )
