"""HLO-text cost model with while-loop trip-count accounting.

XLA's ``compiled.cost_analysis()`` counts every computation ONCE — a scan
body's FLOPs/bytes/collectives are not multiplied by the trip count
(verified in tests/test_roofline.py). Since the whole framework scans over
layers, that undercounts by ~num_layers. This module re-derives the three
roofline inputs by walking the partitioned HLO text:

  * FLOPs: every ``dot`` op = 2 * prod(result_dims) * prod(contracting_dims)
    (batch dims are part of the result); recursed into fusions/calls;
    while bodies multiplied by the trip count parsed from the loop
    condition's scalar ``constant(N)``.
  * collective bytes: result bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute, trip-multiplied.
  * HBM bytes: roofline-grade approximation — per instruction, result bytes
    + named-operand bytes for compute ops (post-fusion HLO ~= one kernel per
    instruction), skipping pure bookkeeping ops.

All shapes in the partitioned module are PER-DEVICE, so every returned
number is per-device.
"""
from __future__ import annotations

import dataclasses
import re
from functools import lru_cache

__all__ = ["module_cost", "Cost"]

_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0,
}
_COLL_WEIGHT = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}
_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "partition-id", "replica-id", "iota",
               "custom-call", "broadcast"}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
# the type is either a tuple "(...)" (may contain /*index=N*/ comments, no
# nested parens) or a single "dtype[dims]{layout}"
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*"
    r"(\([^()]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"([\w\-]+)\((.*)$")
_TRIP_CFG = re.compile(r'known_trip_count"?:\{"?n"?:"?(\d+)')
_OPERAND = re.compile(r"%([\w\.\-]+)")
_CALLS = re.compile(r"calls=%?([\w\.\-]+)")
_WHILE_PARTS = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str):
    m = _SHAPE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0            # link-weighted
    convert_bytes: float = 0.0         # dtype-convert traffic (fuses on TPU)
    coll_raw: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLL_WEIGHT})

    def __iadd__(self, o):
        self.flops += o.flops
        self.bytes += o.bytes
        self.coll_bytes += o.coll_bytes
        self.convert_bytes += o.convert_bytes
        for k in self.coll_raw:
            self.coll_raw[k] += o.coll_raw[k]
        return self

    def scaled(self, m: float) -> "Cost":
        return Cost(self.flops * m, self.bytes * m, self.coll_bytes * m,
                    self.convert_bytes * m,
                    {k: v * m for k, v in self.coll_raw.items()})


def _dus_update_bytes(comp) -> int:
    """Bytes of update operands of dynamic-update-slices in a fused comp."""
    local = {nm: ty for nm, ty, _, _ in comp}
    total = 0
    for nm, ty, op, rest in comp:
        if op == "dynamic-update-slice":
            ops_ = _OPERAND.findall(rest)
            if len(ops_) > 1:
                total += _shape_bytes(local.get(ops_[1], ""))
    return total


def _parse(text: str):
    comps, cur, name = {}, None, None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line)
            if m and "{" in line:
                name, cur = m.group(1), []
            continue
        if line.startswith("}"):
            comps[name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            cur.append((m.group(1), m.group(2), m.group(3), m.group(4)))
    return comps


def _trip_count(comp) -> int:
    """Largest scalar integer constant in the loop condition computation."""
    best = 1
    for _, _, op, rest in comp:
        if op == "constant":
            m = _CONST_INT.search("constant(" + rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def module_cost(text: str) -> Cost:
    comps = _parse(text)
    types = {}                          # global instr name -> type str
    for comp in comps.values():
        for nm, ty, _, _ in comp:
            types[nm] = ty

    # condition computations may reference a constant via a fusion call:
    def cond_trip(cname: str) -> int:
        seen, stack, best = set(), [cname], 1
        while stack:
            c = stack.pop()
            if c in seen or c not in comps:
                continue
            seen.add(c)
            best = max(best, _trip_count(comps[c]))
            for _, _, op, rest in comps[c]:
                mc = _CALLS.search(rest)
                if mc:
                    stack.append(mc.group(1))
        return best

    memo = {}

    def cost_of(cname: str) -> Cost:
        if cname in memo:
            return memo[cname]
        memo[cname] = Cost()            # cycle guard
        total = Cost()
        for nm, ty, op, rest in comps.get(cname, []):
            base = op.replace("-start", "")
            if op == "while":
                m = _WHILE_PARTS.search(rest)
                if m:
                    mt = _TRIP_CFG.search(rest)   # explicit backend_config
                    trip = int(mt.group(1)) if mt else cond_trip(m.group(1))
                    inner = Cost()
                    inner += cost_of(m.group(2))
                    inner += cost_of(m.group(1))
                    total += inner.scaled(trip)
                total.bytes += _shape_bytes(ty)
            elif op == "fusion" or op == "call" or op == "conditional":
                # bytes: 2x result (read-in + write-out amortized). Operand
                # sizes are NOT summed: fusion operands are often whole
                # loop-invariant stacked arrays of which one slice is read
                # per iteration (dynamic-slice), so operand-sum overcounts
                # by O(num_layers). Fusions whose root is a
                # dynamic-update-slice write IN PLACE: charge the update
                # slice, not the full stacked result.
                mc = _CALLS.search(rest)
                dus_bytes = 0
                if mc:
                    inner = cost_of(mc.group(1))
                    total.flops += inner.flops          # fused dots count
                    total.coll_bytes += inner.coll_bytes
                    for kk in total.coll_raw:
                        total.coll_raw[kk] += inner.coll_raw[kk]
                    dus_bytes = _dus_update_bytes(comps.get(mc.group(1), []))
                if dus_bytes:
                    total.bytes += 2.0 * dus_bytes
                else:
                    total.bytes += 2.0 * _shape_bytes(ty)
            elif op == "dynamic-update-slice":
                ops_ = _OPERAND.findall(rest)
                upd = types.get(ops_[1], "") if len(ops_) > 1 else ""
                total.bytes += 2.0 * (_shape_bytes(upd) or _shape_bytes(ty))
            elif op == "dot":
                dims = _shape_dims(ty)
                n = 1
                for d in dims:
                    n *= d
                lhs = _OPERAND.findall(rest)
                lhs_ty = types.get(lhs[0], "") if lhs else ""
                mcd = _CONTRACT.search(rest)
                contract = 1
                if mcd and lhs_ty:
                    ldims = _shape_dims(lhs_ty)
                    for ci in mcd.group(1).split(","):
                        if ci and int(ci) < len(ldims):
                            contract *= ldims[int(ci)]
                total.flops += 2.0 * n * contract
                total.bytes += _shape_bytes(ty)
                for onm in lhs[:2]:
                    total.bytes += _shape_bytes(types.get(onm, ""))
            elif base in _COLL_WEIGHT:
                b = _shape_bytes(ty)
                total.coll_raw[base] += b
                total.coll_bytes += b * _COLL_WEIGHT[base]
                total.bytes += b
            elif op in _SKIP_BYTES or op.endswith("-done"):
                continue
            elif op == "convert" or op == "copy":
                # real traffic on the CPU backend, but TPU fuses dtype
                # converts/copies into producer epilogues: tracked
                # separately so the roofline can report both bounds
                b = 2.0 * _shape_bytes(ty)
                total.bytes += b
                total.convert_bytes += b
            else:
                # generic compute op: read operands'-worth + write result
                total.bytes += 2.0 * _shape_bytes(ty)
        memo[cname] = total
        return total

    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR.match(line)
            if m:
                entry = m.group(1)
            break
    if entry is None:                   # fall back: largest computation
        entry = max(comps, key=lambda c: len(comps[c]))
    return cost_of(entry)
