import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: device count locks at first init.

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell.

For each cell this builds the production mesh (16x16 single-pod or 2x16x16
multi-pod), the ShapeDtypeStruct inputs (never allocated), the sharded
train/prefill/decode step, compiles it AOT, and records:
  * memory_analysis()  — proves the cell fits per-chip HBM
  * cost_analysis()    — per-chip HLO FLOPs / bytes for §Roofline
  * collective bytes   — parsed from the partitioned HLO
Results go to experiments/dryrun/<cell>.json and are summarized into
EXPERIMENTS.md by benchmarks/report.py.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_32b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--quick]
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs.base import ARCH_IDS, SHAPES, ModelConfig, ShapeSpec, get_config
from ..distributed.sharding import (batch_specs, cache_specs,
                                    param_shardings)
from ..models.model import batch_spec, build_model
from ..optim import adamw, constant
from ..train.step import make_train_step
from .mesh import HW, make_production_mesh
from . import roofline as RL

# long_500k runs only for sub-quadratic archs (DESIGN.md §5)
SKIPS = {(a, "long_500k") for a in ARCH_IDS} - {
    ("mamba2_2p7b", "long_500k"), ("jamba15_large", "long_500k")}


def cells(include_skipped: bool = False):
    for arch in ARCH_IDS:
        for shape in SHAPES:
            if (arch, shape) in SKIPS and not include_skipped:
                continue
            yield arch, shape


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    spec = batch_spec(cfg, shape.seq_len, shape.global_batch, shape.kind)
    return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in spec.items()}


def active_params(params_shape, cfg: ModelConfig) -> int:
    """N for MODEL_FLOPS = 6*N*D: active (MoE top-k of E) non-embedding."""
    import numpy as np
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_shape)[0]:
        name = ".".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        n = int(np.prod(leaf.shape))
        last = name.split(".")[-1]
        if last in ("tok", "head"):
            continue                       # 6ND convention: no embeddings
        if last in ("ewg", "ewu", "ewd") and cfg.moe:
            n = n * cfg.moe.top_k // cfg.moe.num_experts
        total += n
    return total


def model_flops(cfg, params_shape, shape: ShapeSpec) -> float:
    n = active_params(params_shape, cfg)
    tokens = shape.global_batch * (1 if shape.kind == "decode"
                                   else shape.seq_len)
    mult = 6 if shape.kind == "train" else 2
    return float(mult) * n * tokens


def build_lowered(arch: str, shape_name: str, multi_pod: bool,
                  remat: str = "full", donate: bool = True,
                  strategy: str = "tp", moe_cap: float = 0.0,
                  attn_chunk: int = 0):
    cfg = get_config(arch)
    import dataclasses
    if moe_cap and cfg.moe:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_per_choice=moe_cap))
    if attn_chunk:
        cfg = dataclasses.replace(cfg, attn_chunk=attn_chunk)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    from ..distributed.ctx import set_batch_axes, set_seq_axes, set_data_size
    gb = SHAPES[shape_name].global_batch
    dsize = 512 if multi_pod else 256
    if strategy != "fsdp":
        dsize //= 16                    # model axis carries TP
    baxes = (("pod", "data", "model") if multi_pod else ("data", "model")) \
        if strategy == "fsdp" else \
        (("pod", "data") if multi_pod else "data")
    set_seq_axes(None)
    set_data_size(dsize if strategy != "fsdp" else dsize // 16)
    if gb % dsize == 0:
        set_batch_axes(baxes)
    elif strategy == "fsdp" and gb % (dsize // 16) == 0:
        # batch too small for all data-like axes: batch over data/pod,
        # SEQUENCE over 'model' (sequence parallelism — prefill cells)
        set_batch_axes(("pod", "data") if multi_pod else "data")
        set_seq_axes("model")
    else:
        set_batch_axes(None)
    bundle = build_model(cfg, remat=remat)
    rng = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(bundle.init, rng)
    psh = param_shardings(mesh, params_shape, strategy=strategy)
    specs = input_specs(cfg, shape)
    with mesh:
        bsh = batch_specs(specs, mesh, strategy=strategy)
        if shape.kind == "train":
            opt = adamw(constant(1e-4))
            opt_shape = jax.eval_shape(opt.init, params_shape)
            osh = param_shardings(mesh, opt_shape["m"], strategy=strategy)
            osh_full = {"m": osh, "v": osh,
                        "step": jax.NamedSharding(
                            mesh, jax.sharding.PartitionSpec())}
            step = make_train_step(bundle, opt)
            jitted = jax.jit(step, in_shardings=(psh, osh_full, bsh),
                             donate_argnums=(0, 1) if donate else ())
            lowered = jitted.lower(params_shape, opt_shape, specs)
        elif shape.kind == "prefill":
            jitted = jax.jit(bundle.prefill, in_shardings=(psh, bsh))
            lowered = jitted.lower(params_shape, specs)
        else:                              # decode
            cache_shape = jax.eval_shape(
                lambda: bundle.init_cache(params_shape, shape.global_batch,
                                          shape.seq_len))
            csh = cache_specs(cache_shape, mesh)
            tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
            tsh = batch_specs({"t": tok}, mesh)["t"]
            jitted = jax.jit(bundle.decode, in_shardings=(psh, tsh, csh),
                             donate_argnums=(2,) if donate else ())
            lowered = jitted.lower(params_shape, tok, cache_shape)
    mf = model_flops(cfg, params_shape, shape)
    return lowered, mesh, mf, cfg


def run_cell(arch: str, shape_name: str, multi_pod: bool, outdir: str,
             remat: str = "full", tag: str = "", strategy: str = "tp",
             moe_cap: float = 0.0, attn_chunk: int = 0) -> dict:
    t0 = time.time()
    lowered, mesh, mf, cfg = build_lowered(arch, shape_name, multi_pod,
                                           remat=remat, strategy=strategy,
                                           moe_cap=moe_cap,
                                           attn_chunk=attn_chunk)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    chips = mesh.devices.size
    rl = RL.analyze(compiled, chips, model_flops=mf)
    row = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16", "tag": tag,
           "t_lower_s": round(t_lower, 1), "t_compile_s": round(t_compile, 1),
           **rl.row()}
    if outdir:
        os.makedirs(outdir, exist_ok=True)
        name = f"{arch}_{shape_name}_{row['mesh']}{tag}.json"
        with open(os.path.join(outdir, name), "w") as f:
            json.dump(row, f, indent=1)
    return row


def fmt_row(row: dict) -> str:
    mem = row.get("peak_memory_per_chip")
    mem_s = f"{mem/2**30:6.1f}GiB" if mem else "   n/a  "
    return (f"{row['arch']:22s} {row['shape']:12s} {row['mesh']:8s} "
            f"tc={row['t_compute_s']:9.3e} tm={row['t_memory_s']:9.3e} "
            f"tl={row['t_collective_s']:9.3e} bound={row['bottleneck']:10s} "
            f"mem={mem_s} useful={row.get('useful_ratio') or 0:6.3f} "
            f"mfu_bound={row.get('mfu_bound') or 0:5.3f} "
            f"[compile {row['t_compile_s']:.0f}s]")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--remat", default="full",
                    choices=["full", "dots", "none"])
    ap.add_argument("--strategy", default="tp", choices=["tp", "fsdp"])
    ap.add_argument("--moe-cap", type=float, default=0.0)
    ap.add_argument("--attn-chunk", type=int, default=0)
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    todo = (list(cells()) if args.all else
            [(args.arch, args.shape or "train_4k")])
    failures = []
    for arch, shape in todo:
        mesh_tag = "2x16x16" if args.multi_pod else "16x16"
        path = os.path.join(args.out,
                            f"{arch}_{shape}_{mesh_tag}{args.tag}.json")
        if args.skip_existing and os.path.exists(path):
            print(f"skip {arch} {shape} (exists)")
            continue
        try:
            row = run_cell(arch, shape, args.multi_pod, args.out,
                           remat=args.remat, tag=args.tag,
                           strategy=args.strategy, moe_cap=args.moe_cap,
                           attn_chunk=args.attn_chunk)
            print(fmt_row(row), flush=True)
        except Exception as e:  # noqa: BLE001
            failures.append((arch, shape, repr(e)))
            print(f"FAIL {arch} {shape}: {e!r}", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} cells failed: {failures}")


if __name__ == "__main__":
    main()
