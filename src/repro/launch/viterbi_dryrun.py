import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import (see dryrun.py).

"""Multi-pod dry-run for the PAPER'S OWN workload: framed Viterbi decoding
at pod scale.

The paper's tiling scheme is also the distribution strategy (DESIGN.md §4):
frames are embarrassingly parallel, so the frame axis shards over every
mesh axis. This lowers + compiles the full receiver (depuncture -> frame ->
forward ACS -> parallel traceback -> stitch) for the 16x16 and 2x16x16
meshes and derives the roofline terms, giving the projected pod-level
decode throughput bound.

  PYTHONPATH=src python -m repro.launch.viterbi_dryrun [--multi-pod]
      [--nbits 100000000] [--rate 1/2]
"""
import argparse
import json

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core.framed import FrameSpec, decode_frame, frame_llr
from ..core.trellis import STD_K7
from .mesh import HW, make_production_mesh
from . import roofline as RL


def build(nbits: int, multi_pod: bool, spec: FrameSpec):
    mesh = make_production_mesh(multi_pod=multi_pod)
    F = spec.num_frames(nbits)
    chips = mesh.devices.size
    F = -(-F // chips) * chips              # pad to an even frame split
    frames = jax.ShapeDtypeStruct((F, spec.frame_len, 2), jnp.float32)
    axes = mesh.axis_names
    fsh = NamedSharding(mesh, P(axes, None, None))
    osh = NamedSharding(mesh, P(axes, None))

    def decode_all(fr):
        return jax.vmap(lambda f: decode_frame(f, STD_K7, spec))(fr)

    with mesh:
        lowered = jax.jit(decode_all, in_shardings=(fsh,),
                          out_shardings=osh).lower(frames)
        compiled = lowered.compile()
    return compiled, mesh, F


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--nbits", type=int, default=100_000_000)
    ap.add_argument("--f", type=int, default=256)
    ap.add_argument("--v2", type=int, default=45)
    ap.add_argument("--f0", type=int, default=32)
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    spec = FrameSpec(f=args.f, v1=20, v2=args.v2, f0=args.f0, v2s=args.v2)
    compiled, mesh, F = build(args.nbits, args.multi_pod, spec)
    chips = mesh.devices.size
    rl = RL.analyze(compiled, chips)
    bits = F * spec.f
    tput = bits / rl.t_bound / 1e9 if rl.t_bound else float("inf")
    row = {"arch": "viterbi_k7", "shape": f"decode_{args.nbits//10**6}Mb",
           "mesh": "2x16x16" if args.multi_pod else "16x16", "tag": "",
           "t_compile_s": 0.0, **rl.row(), "decoded_bits": bits,
           "throughput_bound_gbps": tput}
    print(f"viterbi {row['mesh']}: {F} frames, "
          f"tc={rl.t_compute:.3e} tm={rl.t_memory:.3e} "
          f"tl={rl.t_collective:.3e} bound={rl.bottleneck} "
          f"-> decode bound {tput:.1f} Gb/s "
          f"({tput*1000/chips:.1f} Mb/s/chip)")
    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(
            args.out, f"viterbi_{row['shape']}_{row['mesh']}.json"),
            "w") as fp:
        json.dump(row, fp, indent=1)


if __name__ == "__main__":
    main()
