"""Production training driver.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3_32b --reduced \
      --steps 100 --global-batch 8 --seq 64 --ckpt-dir /tmp/ckpt

On the container this runs reduced configs on CPU; on a real cluster the
same driver runs the full configs on the production mesh (--mesh data,model
picks up all local devices; multi-host initialization is jax.distributed's
standard env-based bootstrap).
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from ..configs.base import ARCH_IDS, get_config
from ..data import DataConfig, SyntheticLM
from ..distributed.sharding import param_shardings
from ..models import build_model
from ..optim import adamw, warmup_cosine
from ..train import (LoopConfig, make_accum_train_step, make_train_step,
                     train_loop)


def make_local_mesh(model_axis: int = 1) -> Mesh:
    devs = np.array(jax.devices())
    data = len(devs) // model_axis
    return Mesh(devs.reshape(data, model_axis), ("data", "model"))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_32b", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data-mode", default="learnable",
                    choices=["learnable", "random"])
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    mesh = make_local_mesh(args.model_axis)
    bundle = build_model(cfg)
    opt = adamw(warmup_cosine(args.lr, 10, args.steps))

    params = bundle.init(jax.random.PRNGKey(0))
    psh = param_shardings(mesh, params)
    params = jax.tree.map(jax.device_put, params, psh)
    state = {"params": params, "opt": opt.init(params)}

    if args.accum > 1:
        raw = make_accum_train_step(bundle, opt, args.accum)
    else:
        raw = make_train_step(bundle, opt)
    with mesh:
        jitted = jax.jit(raw, donate_argnums=(0, 1))

        def step_fn(p, o, batch):
            b = {k: jnp.asarray(v) for k, v in batch.items()}
            if args.accum > 1:
                b = {k: v.reshape(args.accum, v.shape[0] // args.accum,
                                  *v.shape[1:]) for k, v in b.items()}
            return jitted(p, o, b)

        data = SyntheticLM(cfg, DataConfig(args.global_batch, args.seq,
                                           mode=args.data_mode))
        lc = LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                        ckpt_every=args.ckpt_every)
        t0 = time.time()
        stats = train_loop(step_fn, state, data, lc,
                           on_straggler=lambda s, r: print(
                               f"[watchdog] step {s} straggled {r:.1f}x"))
        dt = time.time() - t0
    tok = stats.steps_run * args.global_batch * args.seq
    print(f"done: steps={stats.steps_run} loss={stats.last_loss:.4f} "
          f"restores={stats.restores} stragglers={stats.stragglers} "
          f"tokens/s={tok/dt:.0f}")
    return stats


if __name__ == "__main__":
    main()
