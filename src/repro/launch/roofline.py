"""Roofline-term extraction from a compiled (AOT) jax executable.

    compute term    = HLO_FLOPs_global / (chips * peak_FLOP/s)
    memory term     = HLO_bytes_global / (chips * HBM_bw)
    collective term = collective_link_bytes_per_chip / link_bw

``cost_analysis()`` on an SPMD-partitioned executable reports PER-DEVICE
flops/bytes (verified in tests/test_roofline.py), so global = n_devices x
per-device. Collective bytes are NOT in cost_analysis: we parse the
partitioned HLO text and sum result-shape bytes of every collective op.
Per-chip link traffic for a ring algorithm is ~= result bytes for
all-gather / all-to-all / collective-permute, and ~2x for all-reduce
(reduce-scatter + all-gather phases). reduce-scatter counts its operand
(= result x group) once.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

import numpy as np

from .mesh import HW

__all__ = ["collective_bytes", "Roofline", "analyze"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# result type(s) at the start of an HLO instruction line:
#   %name = bf16[1,2,3]{...} all-gather(...)
#   %name = (f32[8,128]{..}, f32[8,128]{..}) all-to-all(...)
_INSTR = re.compile(
    r"=\s*(\(?[a-z0-9]+\[[^=]*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_WEIGHT = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
           "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(types: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(types):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum per-op-kind result bytes of all collectives in (partitioned) HLO."""
    out = {k: 0 for k in _WEIGHT}
    for m in _INSTR.finditer(hlo_text):
        types, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(types)
    return out


@dataclasses.dataclass
class Roofline:
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float      # link-weighted
    coll_breakdown: dict
    convert_bytes_per_chip: float = 0.0
    peak_memory_per_chip: Optional[float] = None
    model_flops: Optional[float] = None      # 6*N*D useful flops (global)
    xla_flops_oncecounted: float = 0.0       # raw cost_analysis (reference)
    xla_bytes_oncecounted: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / HW.PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HW.HBM_BW

    @property
    def t_memory_fused(self) -> float:
        """Memory term assuming TPU fuses dtype converts (lower bound)."""
        return (self.bytes_per_chip - self.convert_bytes_per_chip) / HW.HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / HW.ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def mfu_bound(self) -> Optional[float]:
        """Model-FLOP utilization upper bound implied by the roofline."""
        if not self.model_flops:
            return None
        ideal = self.model_flops / (self.chips * HW.PEAK_FLOPS_BF16)
        return ideal / self.t_bound if self.t_bound else None

    @property
    def useful_ratio(self) -> Optional[float]:
        if not self.model_flops:
            return None
        return self.model_flops / (self.flops_per_chip * self.chips)

    def row(self) -> dict:
        return {
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "convert_bytes_per_chip": self.convert_bytes_per_chip,
            "t_memory_fused_s": self.t_memory_fused,
            "coll_breakdown": self.coll_breakdown,
            "peak_memory_per_chip": self.peak_memory_per_chip,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "mfu_bound": self.mfu_bound,
            "xla_flops_oncecounted": self.xla_flops_oncecounted,
            "xla_bytes_oncecounted": self.xla_bytes_oncecounted,
        }


def analyze(compiled, chips: int, model_flops: Optional[float] = None
            ) -> Roofline:
    """cost_analysis() counts scan bodies once (tests/test_roofline.py), so
    the primary numbers come from the trip-count-aware HLO walk in
    hlo_cost.py; XLA's own numbers are kept in the row for reference."""
    from .hlo_cost import module_cost
    text = compiled.as_text()
    mc = module_cost(text)
    flops = mc.flops
    byts = mc.bytes
    coll = mc.coll_raw
    coll_w = mc.coll_bytes
    ca = compiled.cost_analysis() or {}
    peak = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            peak = float(getattr(ma, "temp_size_in_bytes", 0)
                         + getattr(ma, "argument_size_in_bytes", 0)
                         + getattr(ma, "output_size_in_bytes", 0)
                         - getattr(ma, "alias_size_in_bytes", 0))
    except Exception:
        peak = None
    rl = Roofline(chips=chips, flops_per_chip=flops, bytes_per_chip=byts,
                  coll_bytes_per_chip=coll_w, coll_breakdown=coll,
                  convert_bytes_per_chip=mc.convert_bytes,
                  peak_memory_per_chip=peak, model_flops=model_flops)
    rl.xla_flops_oncecounted = float(ca.get("flops", 0.0))
    rl.xla_bytes_oncecounted = float(ca.get("bytes accessed", 0.0))
    return rl


def count_params(params_shape) -> int:
    import jax
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params_shape))
