"""Production mesh construction (TPU v5e pods: 16x16 = 256 chips/pod).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "HW"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


class HW:
    """TPU v5e hardware constants for the roofline (per chip)."""
    PEAK_FLOPS_BF16 = 197e12        # FLOP/s
    HBM_BW = 819e9                  # B/s
    ICI_BW = 50e9                   # B/s per link (~ring bandwidth proxy)
    HBM_BYTES = 16 * 2**30          # capacity
