"""Batched LM serving driver: continuous-batching-style loop.

NOTE: this module is the LANGUAGE-MODEL scaffolding demo — it serves
transformer text generation, not convolutional-code decoding. The
multi-tenant *Viterbi* decode service (session scheduler, bucketed
batching, compiled-plan cache) lives in ``repro.serve``; see
examples/serve_viterbi.py.

Requests arrive with different prompt lengths; the server prefills each
prompt (teacher-forced forward), then decodes all live requests in ONE
batched decode step per token, retiring finished requests and admitting
queued ones into freed slots — the standard slot-based continuous batching
used by production LLM servers, here in its synchronous form.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_32b --reduced \
      --requests 6 --slots 4 --gen 16
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from ..configs.base import ARCH_IDS, get_config
from ..models import build_model
from ..models import transformer as T
from ..models import layers as L


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_32b", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=96)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=True)
    if cfg.family == "encdec":
        raise SystemExit("serve demo targets decoder-only archs")
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, rng.integers(4, 12)).tolist()
               for _ in range(args.requests)]
    queue = list(enumerate(prompts))
    B = args.slots
    cache = bundle.init_cache(params, B, args.max_seq)
    decode = jax.jit(bundle.decode, donate_argnums=(2,))

    live = [None] * B                  # per-slot: (req_id, generated, left)
    cur = jnp.zeros((B, 1), jnp.int32)
    done, t0, steps = {}, time.time(), 0

    def admit(slot, cache):
        nonlocal cur
        req_id, prompt = queue.pop(0)
        # prefill the prompt token-by-token into this slot's cache lane
        # (slot-local prefill; a production server batches these too)
        for t in prompt[:-1]:
            tok = cur.at[slot, 0].set(t)
            _, cache = decode(params, tok, cache)
        cur = cur.at[slot, 0].set(prompt[-1])
        live[slot] = (req_id, [], args.gen)
        return cache

    while queue or any(live):
        for s in range(B):
            if live[s] is None and queue:
                cache = admit(s, cache)
        logits, cache = decode(params, cur, cache)
        steps += 1
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for s in range(B):
            if live[s] is None:
                continue
            rid, toks, left = live[s]
            toks.append(int(nxt[s]))
            cur = cur.at[s, 0].set(int(nxt[s]))
            if left - 1 == 0:
                done[rid] = toks
                live[s] = None
            else:
                live[s] = (rid, toks, left - 1)
    dt = time.time() - t0
    for rid in sorted(done):
        print(f"req {rid}: {done[rid][:8]}... ({len(done[rid])} tokens)")
    total = sum(len(v) for v in done.values())
    print(f"served {len(done)} requests, {total} tokens, "
          f"{total/dt:.1f} tok/s, {steps} batched decode steps")
    return done


if __name__ == "__main__":
    main()
