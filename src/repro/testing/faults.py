"""Deterministic fault injection for the decode service and stream layer.

The serve robustness machinery (retry/backoff, deadline, degraded-mode
fallback, quarantine — repro.serve.server) is only testable if the faults
it guards against can be produced ON DEMAND and REPRODUCIBLY. This module
is that harness: a ``FaultInjector`` holds a schedule of ``FaultSpec``
entries and is consulted from three hook points —

  * ``launch(bucket_id)``   — before a batched kernel launch is
    dispatched (``DecodeServer._launch`` / ``StreamDecoder._dispatch``).
    May raise ``InjectedKernelError`` (a failed launch) or sleep
    ``delay_s`` seconds (a slow/hung launch, which the server's
    per-launch deadline then converts into a timeout).
  * ``corrupt(llr, sid=)``  — at the push boundary
    (``DecodeServer.push`` / ``StreamDecoder.push``). Returns the input
    with a ``frac`` fraction of entries overwritten by NaN/Inf/huge
    values (a poisoned tenant); ``sessions`` restricts the blast radius
    to specific session ids.
  * ``plan_cache_miss()``   — before the compiled-plan-cache lookup.
    True forces the server to drop and rebuild the cached program (a
    cold-cache / evicted-plan event).

Schedules are deterministic two ways: ``every=N`` fires on every Nth
event of that kind (exact), and ``p`` fires probabilistically from one
seeded ``numpy`` Generator (reproducible for a fixed seed and call
order). Both can be combined. The injector never mutates its inputs and
keeps per-kind event/injection counters (``stats()``) that the serve
metrics snapshot surfaces next to the retry/degraded counters.

Production code never imports this module unless a ``faults=`` injector
is explicitly passed in — the hooks are ``None``-guarded no-ops.
"""
from __future__ import annotations

import collections
import dataclasses
import time

import numpy as np

__all__ = ["FaultSpec", "FaultInjector", "InjectedFault",
           "InjectedKernelError", "InjectedDeviceLoss", "InjectedCrash",
           "KINDS"]

#: Recognized fault kinds (one hook point each; see module docstring).
#: The durability kinds (PR 8): ``device_loss`` makes every launch of a
#: matching bucket fail persistently over an ``after``/``count`` event
#: window (drives the per-bucket circuit breaker open, then lets the
#: half-open probe succeed once the window expires); ``crash_at_step``
#: raises ``InjectedCrash`` out of ``DecodeServer.step()`` — a simulated
#: process death the kill-restore-compare chaos test recovers from via
#: checkpoint/restore; ``checkpoint_corrupt`` flips bytes in a
#: checkpoint as it is written (the restore path must REJECT it with a
#: structured error, never half-load).
KINDS = ("launch_error", "launch_slow", "corrupt_llr", "plan_cache_miss",
         "device_loss", "crash_at_step", "checkpoint_corrupt")

#: corrupt_llr poison values by mode ('huge' is finite but far beyond any
#: sane LLR magnitude — exercises the out-of-range clamp, not the
#: non-finite scrub).
_POISON = {"nan": np.nan, "inf": np.inf, "huge": np.float32(1e30)}


class InjectedFault(RuntimeError):
    """Base class for every exception raised BY the injector."""


class InjectedKernelError(InjectedFault):
    """An injected kernel-launch failure (stands in for a Pallas/XLA
    compile or runtime error escaping the launch)."""


class InjectedDeviceLoss(InjectedKernelError):
    """An injected PERSISTENT launch failure (stands in for a lost /
    wedged accelerator: every launch on that device fails until the
    fault window closes). Subclasses InjectedKernelError so the serve
    retry machinery sees it as a launch error — the point is that
    retries do NOT clear it, which is what trips the circuit breaker."""


class InjectedCrash(InjectedFault):
    """An injected process crash (raised out of ``DecodeServer.step``,
    NOT caught by the server's own fault handling — the process is
    'dead'; recovery is checkpoint/restore in a fresh server)."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.

    kind:     one of ``KINDS``.
    p:        per-event probability (seeded; 0 disables).
    every:    also fire deterministically on every Nth event (0 disables).
    after:    also fire deterministically on every event from the
              ``after``-th onward (0 disables) — a PERSISTENT fault
              window, bounded by ``count``. This is how device_loss and
              crash_at_step schedules are written.
    count:    with ``after``: how many consecutive events fire (0 =
              unbounded).
    delay_s:  launch_slow — simulated hang duration in seconds.
    mode:     corrupt_llr — 'nan' | 'inf' | 'huge'.
    frac:     corrupt_llr — fraction of entries poisoned (at least one).
    sessions: corrupt_llr — restrict to these session ids (empty = all).
    bucket:   device_loss — restrict to bucket ids containing this
              substring ('' = every bucket; the 'device' that dies).
    """
    kind: str
    p: float = 0.0
    every: int = 0
    after: int = 0
    count: int = 0
    delay_s: float = 0.0
    mode: str = "nan"
    frac: float = 0.25
    sessions: tuple = ()
    bucket: str = ""

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {KINDS}")
        if self.mode not in _POISON:
            raise ValueError(f"unknown corrupt_llr mode {self.mode!r}; "
                             f"expected one of {tuple(_POISON)}")
        if not (0.0 <= self.p <= 1.0 and 0.0 < self.frac <= 1.0
                and self.every >= 0 and self.delay_s >= 0.0
                and self.after >= 0 and self.count >= 0):
            raise ValueError(f"out-of-range FaultSpec parameters: {self}")


class FaultInjector:
    """A seeded schedule of faults, consulted at the serve/stream hooks."""

    def __init__(self, *specs: FaultSpec, seed: int = 0):
        self._specs: dict[str, list[FaultSpec]] = collections.defaultdict(list)
        for s in specs:
            self._specs[s.kind].append(s)
        self._rng = np.random.default_rng(seed)
        self._events = collections.Counter()    # hook calls per kind
        self.injected = collections.Counter()   # faults fired per kind

    def _fire(self, kind: str, accept=None) -> FaultSpec | None:
        """One event of ``kind``: returns the first spec that fires.

        Every spec with p > 0 draws from the seeded generator on every
        event, so the schedule is a pure function of (seed, call order)
        regardless of which specs fire.
        """
        self._events[kind] += 1
        n = self._events[kind]
        hit = None
        for spec in self._specs.get(kind, ()):
            fires = spec.every > 0 and n % spec.every == 0
            if spec.after > 0 and n >= spec.after \
                    and (spec.count == 0 or n < spec.after + spec.count):
                fires = True
            if spec.p > 0.0 and self._rng.random() < spec.p:
                fires = True
            if fires and hit is None and (accept is None or accept(spec)):
                hit = spec
        if hit is not None:
            self.injected[kind] += 1
        return hit

    # -- hooks (all no-ops unless a matching spec fires) -------------------
    def launch(self, bucket_id: str = "") -> None:
        """Launch-path hook: may sleep (slow launch) and/or raise. A
        matching ``device_loss`` spec raises ``InjectedDeviceLoss`` —
        persistent over its after/count window, which is what drives a
        bucket's circuit breaker open."""
        loss = self._fire("device_loss",
                          accept=lambda s: s.bucket in bucket_id)
        if loss is not None:
            raise InjectedDeviceLoss(
                f"injected device loss (bucket {bucket_id or '?'}): every "
                f"launch on this device fails")
        slow = self._fire("launch_slow")
        if slow is not None:
            time.sleep(slow.delay_s)
        if self._fire("launch_error") is not None:
            raise InjectedKernelError(
                f"injected kernel-launch failure (bucket {bucket_id or '?'})")

    def corrupt(self, llr, sid: int | None = None):
        """Push-boundary hook: returns ``llr`` with poisoned entries (a
        copy), or the input untouched when no spec fires."""
        spec = self._fire(
            "corrupt_llr",
            accept=lambda s: not s.sessions or sid in s.sessions)
        arr = np.asarray(llr, np.float32)
        if spec is None or arr.size == 0:
            return llr
        out = arr.copy()
        flat = out.reshape(-1)
        k = max(1, int(spec.frac * flat.size))
        idx = self._rng.choice(flat.size, size=k, replace=False)
        vals = np.full(k, _POISON[spec.mode], np.float32)
        if spec.mode != "nan":                  # both signs of inf/huge
            vals[1::2] = -vals[1::2]
        flat[idx] = vals
        return out

    def plan_cache_miss(self) -> bool:
        """Cache-lookup hook: True forces a rebuild of the cached plan."""
        return self._fire("plan_cache_miss") is not None

    def crash(self, where: str = "step") -> None:
        """Crash hook (``DecodeServer.step`` calls it first thing): a
        firing ``crash_at_step`` spec raises ``InjectedCrash`` — the
        simulated process death. Deliberately OUTSIDE the server's
        try/except fault handling: nothing in the dying process recovers;
        a fresh process restores from the last checkpoint."""
        if self._fire("crash_at_step") is not None:
            raise InjectedCrash(
                f"injected crash at {where} event "
                f"{self._events['crash_at_step']}")

    def checkpoint_bytes(self, data: bytes) -> bytes:
        """Checkpoint-write hook: a firing ``checkpoint_corrupt`` spec
        returns ``data`` with bytes flipped mid-payload (torn/bit-rotted
        write). The restore path must detect it via the CRC and refuse
        to load — never half-restore."""
        if self._fire("checkpoint_corrupt") is None or len(data) < 8:
            return data
        out = bytearray(data)
        mid = len(out) // 2
        for i in range(mid, min(mid + 4, len(out))):
            out[i] ^= 0x5A
        return bytes(out)

    def stats(self) -> dict:
        """JSON-ready counters: hook events seen / faults injected."""
        return {"events": dict(self._events),
                "injected": dict(self.injected)}
