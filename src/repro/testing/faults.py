"""Deterministic fault injection for the decode service and stream layer.

The serve robustness machinery (retry/backoff, deadline, degraded-mode
fallback, quarantine — repro.serve.server) is only testable if the faults
it guards against can be produced ON DEMAND and REPRODUCIBLY. This module
is that harness: a ``FaultInjector`` holds a schedule of ``FaultSpec``
entries and is consulted from three hook points —

  * ``launch(bucket_id)``   — before a batched kernel launch is
    dispatched (``DecodeServer._launch`` / ``StreamDecoder._dispatch``).
    May raise ``InjectedKernelError`` (a failed launch) or sleep
    ``delay_s`` seconds (a slow/hung launch, which the server's
    per-launch deadline then converts into a timeout).
  * ``corrupt(llr, sid=)``  — at the push boundary
    (``DecodeServer.push`` / ``StreamDecoder.push``). Returns the input
    with a ``frac`` fraction of entries overwritten by NaN/Inf/huge
    values (a poisoned tenant); ``sessions`` restricts the blast radius
    to specific session ids.
  * ``plan_cache_miss()``   — before the compiled-plan-cache lookup.
    True forces the server to drop and rebuild the cached program (a
    cold-cache / evicted-plan event).

Schedules are deterministic two ways: ``every=N`` fires on every Nth
event of that kind (exact), and ``p`` fires probabilistically from one
seeded ``numpy`` Generator (reproducible for a fixed seed and call
order). Both can be combined. The injector never mutates its inputs and
keeps per-kind event/injection counters (``stats()``) that the serve
metrics snapshot surfaces next to the retry/degraded counters.

Production code never imports this module unless a ``faults=`` injector
is explicitly passed in — the hooks are ``None``-guarded no-ops.
"""
from __future__ import annotations

import collections
import dataclasses
import time

import numpy as np

__all__ = ["FaultSpec", "FaultInjector", "InjectedFault",
           "InjectedKernelError", "KINDS"]

#: Recognized fault kinds (one hook point each; see module docstring).
KINDS = ("launch_error", "launch_slow", "corrupt_llr", "plan_cache_miss")

#: corrupt_llr poison values by mode ('huge' is finite but far beyond any
#: sane LLR magnitude — exercises the out-of-range clamp, not the
#: non-finite scrub).
_POISON = {"nan": np.nan, "inf": np.inf, "huge": np.float32(1e30)}


class InjectedFault(RuntimeError):
    """Base class for every exception raised BY the injector."""


class InjectedKernelError(InjectedFault):
    """An injected kernel-launch failure (stands in for a Pallas/XLA
    compile or runtime error escaping the launch)."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.

    kind:     one of ``KINDS``.
    p:        per-event probability (seeded; 0 disables).
    every:    also fire deterministically on every Nth event (0 disables).
    delay_s:  launch_slow — simulated hang duration in seconds.
    mode:     corrupt_llr — 'nan' | 'inf' | 'huge'.
    frac:     corrupt_llr — fraction of entries poisoned (at least one).
    sessions: corrupt_llr — restrict to these session ids (empty = all).
    """
    kind: str
    p: float = 0.0
    every: int = 0
    delay_s: float = 0.0
    mode: str = "nan"
    frac: float = 0.25
    sessions: tuple = ()

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {KINDS}")
        if self.mode not in _POISON:
            raise ValueError(f"unknown corrupt_llr mode {self.mode!r}; "
                             f"expected one of {tuple(_POISON)}")
        if not (0.0 <= self.p <= 1.0 and 0.0 < self.frac <= 1.0
                and self.every >= 0 and self.delay_s >= 0.0):
            raise ValueError(f"out-of-range FaultSpec parameters: {self}")


class FaultInjector:
    """A seeded schedule of faults, consulted at the serve/stream hooks."""

    def __init__(self, *specs: FaultSpec, seed: int = 0):
        self._specs: dict[str, list[FaultSpec]] = collections.defaultdict(list)
        for s in specs:
            self._specs[s.kind].append(s)
        self._rng = np.random.default_rng(seed)
        self._events = collections.Counter()    # hook calls per kind
        self.injected = collections.Counter()   # faults fired per kind

    def _fire(self, kind: str, accept=None) -> FaultSpec | None:
        """One event of ``kind``: returns the first spec that fires.

        Every spec with p > 0 draws from the seeded generator on every
        event, so the schedule is a pure function of (seed, call order)
        regardless of which specs fire.
        """
        self._events[kind] += 1
        n = self._events[kind]
        hit = None
        for spec in self._specs.get(kind, ()):
            fires = spec.every > 0 and n % spec.every == 0
            if spec.p > 0.0 and self._rng.random() < spec.p:
                fires = True
            if fires and hit is None and (accept is None or accept(spec)):
                hit = spec
        if hit is not None:
            self.injected[kind] += 1
        return hit

    # -- hooks (all no-ops unless a matching spec fires) -------------------
    def launch(self, bucket_id: str = "") -> None:
        """Launch-path hook: may sleep (slow launch) and/or raise."""
        slow = self._fire("launch_slow")
        if slow is not None:
            time.sleep(slow.delay_s)
        if self._fire("launch_error") is not None:
            raise InjectedKernelError(
                f"injected kernel-launch failure (bucket {bucket_id or '?'})")

    def corrupt(self, llr, sid: int | None = None):
        """Push-boundary hook: returns ``llr`` with poisoned entries (a
        copy), or the input untouched when no spec fires."""
        spec = self._fire(
            "corrupt_llr",
            accept=lambda s: not s.sessions or sid in s.sessions)
        arr = np.asarray(llr, np.float32)
        if spec is None or arr.size == 0:
            return llr
        out = arr.copy()
        flat = out.reshape(-1)
        k = max(1, int(spec.frac * flat.size))
        idx = self._rng.choice(flat.size, size=k, replace=False)
        vals = np.full(k, _POISON[spec.mode], np.float32)
        if spec.mode != "nan":                  # both signs of inf/huge
            vals[1::2] = -vals[1::2]
        flat[idx] = vals
        return out

    def plan_cache_miss(self) -> bool:
        """Cache-lookup hook: True forces a rebuild of the cached plan."""
        return self._fire("plan_cache_miss") is not None

    def stats(self) -> dict:
        """JSON-ready counters: hook events seen / faults injected."""
        return {"events": dict(self._events),
                "injected": dict(self.injected)}
