"""Deterministic test harnesses (fault injection for the decode service)."""
from .faults import (FaultInjector, FaultSpec,           # noqa: F401
                     InjectedFault, InjectedKernelError)

__all__ = ["FaultInjector", "FaultSpec", "InjectedFault",
           "InjectedKernelError"]
