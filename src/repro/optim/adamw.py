"""AdamW from scratch (no optax in this environment).

bf16 params + fp32 moments (DESIGN.md §4); global-norm clipping; decoupled
weight decay (skipped for 1-D leaves: norms/biases). Functional init/update
pair; moments inherit the param sharding (the launch layer may additionally
shard them over the 'pod' axis — ZeRO-style — see distributed/sharding.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamW", "adamw"]


class AdamW(NamedTuple):
    init: Callable
    update: Callable


def adamw(lr: Callable | float, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          clip_norm: float = 1.0) -> AdamW:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
        step = state["step"] + 1
        lr_t = lr_fn(step)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g * scale
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if p.ndim >= 2:                      # decoupled WD, matrices only
                u = u + weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - lr_t * u
            return newp.astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        newp = jax.tree.map(lambda t: t[0], out,
                            is_leaf=lambda t: isinstance(t, tuple))
        newm = jax.tree.map(lambda t: t[1], out,
                            is_leaf=lambda t: isinstance(t, tuple))
        newv = jax.tree.map(lambda t: t[2], out,
                            is_leaf=lambda t: isinstance(t, tuple))
        return newp, {"m": newm, "v": newv, "step": step}, {
            "grad_norm": gnorm, "lr": lr_t}

    return AdamW(init, update)
