"""LR schedules (warmup + cosine decay), pure functions of the step."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["warmup_cosine", "constant"]


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def warmup_cosine(peak: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor * peak + (1 - floor) * peak * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)
    return lr
