from .adamw import adamw, AdamW            # noqa: F401
from .schedule import warmup_cosine, constant  # noqa: F401
