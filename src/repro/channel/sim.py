"""Verification system of paper Fig. 8 (§V-B).

bits -> convolutional encoder -> (puncture) -> BPSK -> AWGN(Eb/N0)
     -> (depuncture) -> decoder -> BER vs. the original bits.

Also provides the theoretical union-bound BER curve the paper compares
against (their MATLAB ``bertool`` reference) and the paper's "distance in
Eb/N0" metric used by Tables II/III.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import numpy as np
import scipy.special as sps
import jax
import jax.numpy as jnp

from ..core.encoder import encode
from ..core.puncture import puncture, depuncture, punctured_rate
from ..core.trellis import Trellis, STD_K7

__all__ = ["bpsk", "awgn", "ber", "simulate", "theoretical_ber",
           "ebn0_distance_metric"]


def bpsk(bits: jax.Array) -> jax.Array:
    """bit 0 -> +1.0, bit 1 -> -1.0 (matches the LLR sign convention)."""
    return 1.0 - 2.0 * bits.astype(jnp.float32)


def awgn(key: jax.Array, x: jax.Array, ebn0_db: float) -> jax.Array:
    """AWGN with sigma = 10^(-EbN0dB/20), the paper's simulation recipe."""
    sigma = 10.0 ** (-ebn0_db / 20.0)
    return x + sigma * jax.random.normal(key, x.shape, jnp.float32)


def ber(decoded: jax.Array, truth: jax.Array) -> jax.Array:
    return jnp.mean((decoded != truth).astype(jnp.float32))


@partial(jax.jit, static_argnums=(1, 2, 3, 4))
def _channel(key, n: int, ebn0_db: float, rate: str, trellis: Trellis):
    kb, kn = jax.random.split(key)
    bits = jax.random.bernoulli(kb, 0.5, (n,)).astype(jnp.int32)
    coded = encode(bits, trellis)                     # (n, beta)
    tx = bpsk(puncture(coded, rate))                  # punctured stream
    rx = awgn(kn, tx, ebn0_db)                        # soft symbols ~ LLRs
    llr = depuncture(rx, rate, n)                     # (n, beta), 0 = erased
    return bits, llr


def simulate(key: jax.Array, n: int, ebn0_db: float,
             decoder: Callable[[jax.Array], jax.Array],
             rate: str = "1/2", trellis: Trellis = STD_K7,
             hard: bool = False):
    """Run Fig. 8 once; returns (ber, bits, decoded).

    ``decoder`` maps (n, beta) llr -> (n,) bits — any of: full reference,
    framed (serial/parallel traceback), or the Pallas unified kernel.
    ``hard=True`` slices the soft symbols to ±1 (hard-decision mode,
    paper §II-C — costs ~2.3 dB of BER).
    BER is trustworthy only when it exceeds 100/n (paper's rule of thumb).
    """
    bits, llr = _channel(key, n, ebn0_db, rate, trellis)
    if hard:
        llr = jnp.sign(llr)
    decoded = decoder(llr)
    return float(ber(decoded, bits)), bits, decoded


# ---------------------------------------------------------------------------
# Theory: union bound for the standard K=7 (171,133) code. Distance spectrum
# coefficients c_d (information-bit weights) from the literature.
_SPECTRUM_K7 = {10: 36, 12: 211, 14: 1404, 16: 11633, 18: 77433, 20: 502690}


def _q(x):
    return 0.5 * sps.erfc(np.asarray(x) / np.sqrt(2.0))


def theoretical_ber(ebn0_db: np.ndarray, rate: float = 0.5,
                    spectrum: dict = _SPECTRUM_K7) -> np.ndarray:
    """Union-bound BER for soft-decision ML decoding (tight above ~4 dB)."""
    ebn0 = 10.0 ** (np.asarray(ebn0_db, dtype=np.float64) / 10.0)
    out = np.zeros_like(ebn0)
    for d, c in spectrum.items():
        out = out + c * _q(np.sqrt(2.0 * d * rate * ebn0))
    return out


def ebn0_distance_metric(ebn0_db: np.ndarray, ber_meas: np.ndarray,
                         rate: float = 0.5) -> float:
    """Paper Tables II/III metric: horizontal (Eb/N0) distance between the
    measured BER curve and the theoretical one, averaged over the overlap.

    For each measured (ebn0, ber) point, find the Eb/N0 at which theory
    reaches the same BER and average the dB gaps.
    """
    grid = np.linspace(0.0, 12.0, 1201)
    th = theoretical_ber(grid, rate)
    gaps = []
    for e, b in zip(np.asarray(ebn0_db), np.asarray(ber_meas)):
        if b <= 0 or b >= 0.4:
            continue
        # theory BER is monotonically decreasing in Eb/N0
        idx = np.searchsorted(-np.log10(th), -np.log10(b))
        idx = min(max(idx, 0), len(grid) - 1)
        gaps.append(e - grid[idx])
    return float(np.mean(gaps)) if gaps else float("nan")
