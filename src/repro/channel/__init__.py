from .sim import (awgn, bpsk, ber, simulate, theoretical_ber,
                  ebn0_distance_metric)  # noqa: F401
