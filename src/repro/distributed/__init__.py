from .sharding import (param_specs, param_shardings, batch_specs,
                       cache_specs, moment_specs)  # noqa: F401
from . import compress                             # noqa: F401
from .stream import frame_mesh, make_sharded_frame_decoder  # noqa: F401
