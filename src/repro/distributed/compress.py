"""int8 gradient compression with error feedback (distributed-optimization
trick for the DP axis; DESIGN.md §4).

Scheme (1-bit-Adam-family, simplified to int8):
  1. g_corr = g_local + ef                    (error feedback carry-in)
  2. scale  = psum_max(|g_corr|) / 127        (one scalar collective)
  3. q      = round(g_corr / scale)  int8     (4x smaller than fp32 on wire)
  4. g_hat  = psum(q) * scale / n_devices
  5. ef'    = g_corr - dequant(q) * scale     (local quantization residual)

Implemented with shard_map over the 'data' axis so the collective operand
really is the int8 tensor (under plain pjit the all-reduce would be fp32).
Params are replicated across 'data' in this path (pure-DP demonstration;
the FSDP path uses standard fp32 grads).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:                                       # jax >= 0.6 re-export
    from jax import shard_map
except ImportError:                        # jax 0.4.x / 0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kw):
        # old API spells the arg check_rep; translate and drop unknowns
        if check_vma is not None:
            kw.setdefault("check_rep", check_vma)
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kw)

__all__ = ["init_ef", "compressed_grads", "make_compressed_train_step"]


def init_ef(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _compress_one(g, ef, axis):
    g = g.astype(jnp.float32) + ef
    amax = jax.lax.pmax(jnp.max(jnp.abs(g)), axis)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    n = jax.lax.psum(1, axis)
    g_hat = jax.lax.psum(q.astype(jnp.int32), axis).astype(jnp.float32)
    g_hat = g_hat * scale / n
    return g_hat, g - deq


def compressed_grads(grads, ef, axis: str):
    """Inside shard_map: all-reduce int8-compressed grads w/ error feedback."""
    out = jax.tree.map(lambda g, e: _compress_one(g, e, axis), grads, ef)
    g_hat = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return g_hat, new_ef


def make_compressed_train_step(loss_fn, optimizer, mesh: Mesh,
                               axis: str = "data"):
    """Pure-DP train step with int8 grad all-reduce.

    params/opt_state/ef replicated; batch sharded over ``axis``.
    """
    def step(params, opt_state, ef, batch):
        def inner(params, opt_state, ef, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            loss = jax.lax.pmean(loss, axis)
            g_hat, ef = compressed_grads(grads, ef, axis)
            params, opt_state, metrics = optimizer.update(
                g_hat, opt_state, params)
            return params, opt_state, ef, {"loss": loss, **metrics}

        spec_rep = jax.tree.map(lambda _: P(), params)

        inner_sm = shard_map(
            inner, mesh=mesh,
            in_specs=(spec_rep, jax.tree.map(lambda _: P(), opt_state),
                      jax.tree.map(lambda _: P(), ef),
                      jax.tree.map(lambda _: P(axis), batch)),
            out_specs=(spec_rep, jax.tree.map(lambda _: P(), opt_state),
                       jax.tree.map(lambda _: P(), ef),
                       {"loss": P(), "grad_norm": P(), "lr": P()}),
            check_vma=False)
        return inner_sm(params, opt_state, ef, batch)

    return jax.jit(step)
