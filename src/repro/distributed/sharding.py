"""Logical-axis sharding rules -> NamedSharding trees.

TP over 'model' (heads / d_ff / experts / vocab), FSDP-style weight sharding
over 'data', batch over ('pod', 'data'). Rules are right-aligned to the
trailing dims so the stacked layer axis (leading R) stays unsharded; GSPMD
pads non-divisible dims (e.g. 40 heads on 16-way model axis) internally.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["param_specs", "param_shardings", "batch_specs", "cache_specs",
           "moment_specs"]

# (regex on the leaf's dotted path, spec for the TRAILING dims)
_RULES = [
    (r"\btok$",                       ("model", "data")),
    (r"\bhead$",                      ("data", "model")),
    (r"\b(wq|wk|wv|wqkv|wg|wu|in_proj)$",  ("data", "model")),
    (r"\b(wo|wd|out_proj)$",          ("model", "data")),
    (r"\brouter$",                    ("data", None)),
    (r"\b(ewg|ewu)$",                 ("model", "data", None)),
    (r"\bewd$",                       ("model", None, "data")),
    (r"\b(bq|bk|bv|bqkv|conv_b|A_log|dt_bias)$", ("model",)),
    (r"\bD$",                         ("model",)),
    (r"\bconv_w$",                    (None, "model")),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
    return ".".join(parts)


def _spec_for(path: str, ndim: int, data_axes) -> P:
    for pat, trailing in _RULES:
        if re.search(pat, path):
            if len(trailing) > ndim:       # scalar-ish leaf, replicate
                return P()
            axes = [None] * (ndim - len(trailing)) + [
                (data_axes if a == "data" else a) for a in trailing]
            return P(*axes)
    return P()                             # norms / scalars: replicated


def param_specs(params, shard_data: bool = True, data_axes="data",
                strategy: str = "tp") -> "jax.tree":
    """Tree of PartitionSpec matching ``params``.

    strategy:
      'tp'   — TP over 'model' (heads/ffn/experts/vocab) + FSDP over 'data'
               (the baseline).
      'fsdp' — pure FSDP/ZeRO-3: weight matrices sharded over
               ('data','model') on their (previously-)data dim, no TP
               contraction all-reduces. Expert dims (ewg/ewu/ewd) keep EP
               over 'model'. Batch then shards over BOTH axes.
    shard_data=False turns off the FSDP dimension (pure-TP params), used by
    small-model tests and the compressed-DP path.
    """
    fsdp_axes = (tuple(data_axes) if isinstance(data_axes, tuple)
                 else (data_axes,)) + ("model",)

    def one(path, leaf):
        name = _path_str(path)
        spec = _spec_for(name, leaf.ndim, data_axes)
        if strategy == "fsdp" and not re.search(r"\b(ewg|ewu|ewd)$", name):
            spec = P(*[fsdp_axes if a == data_axes or a == "data"
                       else (None if a == "model" else a) for a in spec])
        if not shard_data:
            spec = P(*[None if a in ("data", data_axes) else a for a in spec])
        return spec
    return jax.tree_util.tree_map_with_path(one, params)


def moment_specs(params, zero_pod: bool = False):
    """Optimizer-moment specs: same as params, optionally sharding the
    'data'-sharded dim over ('pod','data') (ZeRO over pods)."""
    base = param_specs(params,
                       data_axes=("pod", "data") if zero_pod else "data")
    return base


def param_shardings(mesh: Mesh, params, **kw):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, **kw))


def batch_specs(batch, mesh: Mesh, strategy: str = "tp"):
    """Batch dim over all data-like mesh axes present (replicated when the
    global batch doesn't divide them, e.g. long_500k's batch of 1). In
    'fsdp' strategy the 'model' axis is data-like too."""
    names = ("pod", "data", "model") if strategy == "fsdp" else ("pod", "data")
    axes = tuple(a for a in names if a in mesh.axis_names)
    ax = axes if len(axes) > 1 else (axes[0] if axes else None)
    dsize = 1
    for a in names:
        dsize *= mesh.shape.get(a, 1)

    def one(leaf):
        if leaf.ndim == 0 or leaf.shape[0] % dsize:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(ax, *([None] * (leaf.ndim - 1))))
    return jax.tree.map(one, batch)


def cache_specs(cache, mesh: Mesh):
    """Decode-state shardings.

    KV caches (R, B, S, KV, hd): batch over data axes (when divisible);
    KV heads over 'model' when divisible, else the SEQUENCE dim over
    'model' (sequence-parallel cache — the long_500k path for archs whose
    kv count doesn't divide the model axis).
    SSM states (R, B, H, N, P): heads over 'model'. Conv states (R, B, K,
    C): channels over 'model'.
    """
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    ax = axes if len(axes) > 1 else (axes[0] if axes else None)
    msize = mesh.shape.get("model", 1)
    dsize = 1
    for a in ("pod", "data"):
        dsize *= mesh.shape.get(a, 1)

    def one(path, leaf):
        name = _path_str(path)
        if leaf.ndim <= 1 or "idx" in name:
            return NamedSharding(mesh, P())
        spec = [None] * leaf.ndim
        bdim = 1 if leaf.ndim >= 3 else 0
        if leaf.shape[bdim] % dsize == 0:
            spec[bdim] = ax
        last = name.split(".")[-1]
        if last in ("k", "v", "xk", "xv") and leaf.ndim == 5:
            if leaf.shape[3] % msize == 0:
                spec[3] = "model"          # kv heads
            elif leaf.shape[2] % msize == 0:
                spec[2] = "model"          # sequence-parallel cache
        elif last == "ssm" and leaf.ndim == 5:        # (R,B,H,N,P)
            if leaf.shape[2] % msize == 0:
                spec[2] = "model"
        elif last == "conv" and leaf.ndim == 4:       # (R,B,K,C)
            if leaf.shape[3] % msize == 0:
                spec[3] = "model"
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, cache)
