"""Sharded frame decode: the paper's tiling is also the distribution axis.

Frames are embarrassingly parallel (core/framed.py), so the multi-device
strategy is one line of placement: tile the frame axis of each chunk
across a 1-D 'frames' mesh with shard_map and run the per-device frame
decoder (reference or Pallas kernel backend) on each shard. Used by the
streaming front-end (core/stream.py, ``mesh=`` argument) so every pushed
chunk is decoded by all devices at once; the chunk size from
``kernels.autotune.plan_decode`` is a multiple of tiles x devices, so each
device receives whole kernel tiles.

The per-device VMEM budget of the tile plan is unchanged by sharding —
every device runs its own grid over its own frame shard — which is why
``plan_decode(num_devices=...)`` scales only the chunk geometry, not the
tile footprint.

The multi-tenant serve layer rides the same path: a ``DecodeServer``
built with ``mesh=...`` decodes each bucket's ``slots x chunk_frames``
batch through this sharded decoder (the batch IS the frame axis), and the
compiled-plan cache (serve/plan_cache.py) memoizes one sharded closure
per (cfg, mesh) so bucket churn re-uses the shard_map trace too.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..core.pipeline import DecoderConfig, make_frame_decoder
from .compress import shard_map

__all__ = ["frame_mesh", "make_sharded_frame_decoder"]


def frame_mesh(devices=None) -> Mesh:
    """1-D mesh over all (or the given) local devices, axis 'frames'."""
    devs = np.array(jax.devices() if devices is None else devices)
    return Mesh(devs, ("frames",))


def make_sharded_frame_decoder(cfg: DecoderConfig, mesh: Mesh | None = None):
    """Returns decode_frames((F, L, beta)) -> (F, f) bits, frame-sharded.

    F is padded up to a multiple of the mesh size (padding frames decode
    garbage from zero LLRs and are dropped before returning). Each shard
    runs the ordinary per-device frame decoder (the cache-shared closure
    from make_frame_decoder), so every cfg backend — reference, unified
    kernel, split kernel — shards identically.
    """
    mesh = mesh if mesh is not None else frame_mesh()
    local = make_frame_decoder(cfg)
    ndev = int(mesh.devices.size)

    def decode_frames(frames: jax.Array) -> jax.Array:
        F = frames.shape[0]
        Fp = -(-F // ndev) * ndev
        if Fp != F:
            frames = jnp.pad(frames, ((0, Fp - F), (0, 0), (0, 0)))
        sharded = shard_map(local, mesh=mesh, in_specs=P("frames"),
                            out_specs=P("frames"), check_vma=False)
        return sharded(frames)[:F]

    return decode_frames
