"""Activation-sharding context.

Model code is mesh-agnostic; the launch layer declares which mesh axes carry
the batch dim (('pod','data') / ('data',)) and model code pins activations
to it at layer boundaries via ``constrain_batch``. Without this, GSPMD
propagates the FSDP param sharding INTO activations (observed in the first
dry-run: batch replicated, d_model sharded over 'data' — catastrophic for
both memory and collectives). No-op when no axes are set (tests, CPU runs).
"""
from __future__ import annotations

import contextlib

import jax
from jax.sharding import PartitionSpec as P

_BATCH_AXES = None
_SEQ_AXES = None
_DATA_SIZE = None    # product of the data-like axis sizes (divisibility)


def set_batch_axes(axes):
    """axes: None | str | tuple — mesh axes of the global batch dim."""
    global _BATCH_AXES
    _BATCH_AXES = axes


def set_seq_axes(axes):
    """Sequence-parallel residual stream: mesh axes of dim 1 (seq) of
    (B, S, d) activations. Used when the batch is too small to cover the
    data-like axes (e.g. prefill_32k at batch 32 on 256 chips)."""
    global _SEQ_AXES
    _SEQ_AXES = axes


def set_data_size(n):
    global _DATA_SIZE
    _DATA_SIZE = n


def get_data_size():
    return _DATA_SIZE


def get_batch_axes():
    return _BATCH_AXES


@contextlib.contextmanager
def batch_axes(axes):
    prev = _BATCH_AXES
    set_batch_axes(axes)
    try:
        yield
    finally:
        set_batch_axes(prev)


def constrain_batch(x: jax.Array) -> jax.Array:
    """Pin dim 0 of ``x`` to the batch axes (+ dim 1 to the seq axes when
    sequence parallelism is on), rest unconstrained."""
    if (_BATCH_AXES is None and _SEQ_AXES is None) or x.ndim == 0:
        return x
    rest = [None] * (x.ndim - 1)
    if _SEQ_AXES is not None and x.ndim >= 3:
        rest[0] = _SEQ_AXES
    spec = P(_BATCH_AXES, *rest)
    return jax.lax.with_sharding_constraint(x, spec)
