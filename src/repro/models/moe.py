"""Mixture-of-Experts FF layer (top-k routing, capacity-bounded dispatch).

Dispatch strategy (DESIGN.md §4): tokens are split into routing groups of
``group_size``; each of the k routing choices is dispatched as an
independent top-1 one-hot einsum with per-choice capacity
``C1 = ceil(group_size * capacity_per_choice / num_experts)``. Splitting the
k choices keeps the dispatch tensor (G, g, E, C1) k-times smaller than the
classic GShard combine tensor while remaining a pure einsum — the known
GSPMD-friendly form (expert dim sharded over 'model' = EP; tokens sharded
over 'data' = DP; the dispatch einsums lower to all-to-alls).

Routing correctness (weights, renorm, capacity drops) is oracle-tested
against a per-token python loop in tests/test_moe.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed import ctx
from .layers import Init

__all__ = ["init_moe", "moe_ff"]


def _constrain_expert(t: jax.Array) -> jax.Array:
    """Pin (E, G, C, ...) expert buffers: experts over 'model' (EP), groups
    over the batch axes WHEN divisible (decode steps have G=1: constraining
    it would force GSPMD padding/replication — §Perf hc2 decode regression).
    No-op outside a mesh context."""
    axes = ctx.get_batch_axes()
    if axes is None:
        return t
    from jax.sharding import PartitionSpec as P
    gax = tuple(a for a in (axes if isinstance(axes, tuple) else (axes,))
                if a != "model") or None
    if isinstance(gax, tuple) and len(gax) == 1:
        gax = gax[0]
    n = ctx.get_data_size()
    if gax is None or not n or t.shape[1] % n:
        gax = None
    return jax.lax.with_sharding_constraint(
        t, P("model", gax, *([None] * (t.ndim - 2))))


def init_moe(key, cfg: ModelConfig) -> dict:
    m = cfg.moe
    d, E, ff = cfg.d_model, m.num_experts, m.d_ff_expert
    ks = jax.random.split(key, 5)
    dt = cfg.param_dtype
    p = {
        "router": Init(ks[0], (d, E), jnp.float32),
        "ewg": Init(ks[1], (E, d, ff), dt),
        "ewu": Init(ks[2], (E, d, ff), dt),
        "ewd": Init(ks[3], (E, ff, d), dt),
    }
    if m.shared_expert:
        sk = jax.random.split(ks[4], 3)
        p["shared"] = {"wg": Init(sk[0], (d, ff), dt),
                       "wu": Init(sk[1], (d, ff), dt),
                       "wd": Init(sk[2], (ff, d), dt)}
    return p


def moe_ff(p: dict, x: jax.Array, cfg: ModelConfig):
    """x: (B, S, d) -> (y (B, S, d), aux_loss scalar)."""
    m = cfg.moe
    B, S, d = x.shape
    E, k = m.num_experts, m.top_k
    T = B * S
    g = min(m.group_size, T)
    while T % g:                      # largest divisor of T <= group_size
        g -= 1
    G = T // g
    C1 = max(1, int(-(-g * m.capacity_per_choice // E)))

    xt = x.reshape(G, g, d)
    rl = (xt.astype(jnp.float32) @ p["router"])          # (G, g, E)
    probs = jax.nn.softmax(rl, axis=-1)

    # load-balance aux (Switch/GShard): E * mean_e(frac_tokens * mean_prob)
    top1 = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, E, dtype=jnp.float32), axis=(0, 1))
    aux = E * jnp.sum(frac * jnp.mean(probs, axis=(0, 1)))

    # --- build the k dispatch/combine one-hots, CONCATENATED along the
    # capacity axis (C = k*C1): dispatch, expert FF and combine then run
    # ONCE instead of k times, so the inherent EP all-reduces of the
    # dispatch/combine contractions happen 1x/layer instead of k x/layer
    # (8x link-traffic cut for top-8; EXPERIMENTS.md §Perf hc2).
    remaining = probs
    disp_k, comb_k = [], []
    wsum = jnp.zeros((G, g), jnp.float32)
    for _ in range(k):                                   # static top-k loop
        w_j = remaining.max(axis=-1)                     # (G, g)
        e_j = remaining.argmax(axis=-1)                  # (G, g)
        oh_e = jax.nn.one_hot(e_j, E, dtype=jnp.float32)          # (G,g,E)
        remaining = remaining * (1.0 - oh_e)
        pos = jnp.cumsum(oh_e, axis=1) - 1.0                      # (G,g,E)
        pos_tok = jnp.einsum("gte,gte->gt", pos, oh_e)            # (G,g)
        keep = pos_tok < C1
        oh_c = jax.nn.one_hot(pos_tok.astype(jnp.int32), C1,
                              dtype=jnp.float32) * keep[..., None]
        disp = jnp.einsum("gte,gtc->gtec", oh_e, oh_c).astype(x.dtype)
        disp_k.append(disp)                              # (G,g,E,C1)
        comb_k.append(disp * w_j[..., None, None].astype(x.dtype))
        wsum = wsum + w_j * keep                         # dropped -> no w
    def expert_ff(disp, comb, constrain):
        # keep the (sharded) group dim G through the expert compute: the
        # dispatch lowers to a token->expert all-to-all instead of the
        # all-gather a G*C merge would force
        xin = jnp.einsum("gtec,gtd->egcd", disp, xt)     # (E,G,C,d)
        if constrain:
            xin = _constrain_expert(xin)
        h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xin, p["ewg"]))
        h = h * jnp.einsum("egcd,edf->egcf", xin, p["ewu"])
        yo = jnp.einsum("egcf,efd->egcd", h, p["ewd"])   # (E,G,C,d)
        if constrain:
            yo = _constrain_expert(yo)
        return jnp.einsum("gtec,egcd->gtd", comb, yo)    # (G,g,d)

    if T >= 4 * m.group_size:
        # training/prefill scale: fused dispatch — one EP all-reduce per
        # layer instead of k (8x link cut for top-8, §Perf hc2b)
        y = expert_ff(jnp.concatenate(disp_k, axis=-1),
                      jnp.concatenate(comb_k, axis=-1), True)
    else:
        # decode/tiny-batch: k small per-choice dispatches beat one fat
        # concat-C exchange, and forcing EP sharding on a single token
        # group only adds resharding (measured, §Perf hc2 decode note)
        y = sum(expert_ff(d_, c_, False) for d_, c_ in zip(disp_k, comb_k))
    y = y / jnp.maximum(wsum[..., None], 1e-9).astype(x.dtype)

    if m.shared_expert:
        sp = p["shared"]
        y = y + (jax.nn.silu(xt @ sp["wg"]) * (xt @ sp["wu"])) @ sp["wd"]
    return y.reshape(B, S, d), aux
