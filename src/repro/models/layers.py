"""Dense building blocks: norms, RoPE, GQA attention (full / blockwise /
decode-with-cache), gated MLP, embeddings, losses.

Conventions:
  * params are plain dict pytrees; init_* builds one layer's params,
    transformer.py stacks layers and scans.
  * activations follow cfg.dtype (bf16); norms/softmax/logsumexp in fp32.
  * attention is flash-style blockwise (scan over kv chunks, online softmax)
    whenever seq_len > cfg.attn_chunk, so S x S never materializes.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig

Init = jax.nn.initializers.normal(stddev=0.02)


# ---------------------------------------------------------------- norms ----
@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rms_norm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    """RMSNorm with fp32 statistics but activation-dtype tensors end-to-end.

    custom_vjp so the backward also stays in x.dtype: the autodiff vjp of
    the fp32-upcast formulation produces fp32 (B,S,d) cotangents that then
    flow into the TP all-reduces at fp32 — 2x link and HBM traffic for no
    accuracy benefit (fp32 is kept exactly where it matters: the variance
    and dw reductions). See EXPERIMENTS.md §Perf iteration 0.
    """
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * w.astype(x.dtype)


def _rms_fwd(x, w, eps):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv32 = jax.lax.rsqrt(var + eps)
    return x * inv32.astype(x.dtype) * w.astype(x.dtype), (x, w, inv32)


def _rms_bwd(eps, res, dy):
    x, w, inv32 = res
    inv = inv32.astype(x.dtype)
    t = dy * w.astype(x.dtype)                       # bf16
    # d/dx of x*inv: inv*t - x * inv^3 * mean(t*x) (fp32 reduction only)
    s = jnp.mean((t * x).astype(jnp.float32), axis=-1, keepdims=True)
    dx = t * inv - x * ((inv32 ** 3) * s).astype(x.dtype)
    dw = jnp.sum((dy * x * inv).astype(jnp.float32),
                 axis=tuple(range(dy.ndim - 1))).astype(w.dtype)
    return dx, dw


rms_norm.defvjp(_rms_fwd, _rms_bwd)


# ----------------------------------------------------------------- rope ----
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd), positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs    # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------ attention ----
def init_attention(key, cfg: ModelConfig, fused: bool = False) -> dict:
    """fused=True stores one wqkv matrix: a single projection dot instead
    of three. REFUTED as a default (§Perf hc3c): under TP the q/k/v split
    points don't align with the model-axis shard boundaries, so GSPMD
    inserts resharding collectives (+20%% link bytes on prefill_32k).
    Kept as an option for FSDP-sharded runs where it is mildly positive."""
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    dt = cfg.param_dtype
    if fused:
        p = {"wqkv": Init(ks[0], (d, (H + 2 * KV) * hd), dt),
             "wo": Init(ks[3], (H * hd, d), dt)}
        if cfg.qkv_bias:
            p["bqkv"] = jnp.zeros(((H + 2 * KV) * hd,), dt)
    else:
        p = {
            "wq": Init(ks[0], (d, H * hd), dt),
            "wk": Init(ks[1], (d, KV * hd), dt),
            "wv": Init(ks[2], (d, KV * hd), dt),
            "wo": Init(ks[3], (H * hd, d), dt),
        }
        if cfg.qkv_bias:
            p["bq"] = jnp.zeros((H * hd,), dt)
            p["bk"] = jnp.zeros((KV * hd,), dt)
            p["bv"] = jnp.zeros((KV * hd,), dt)
    if cfg.qk_norm:
        p["qn"] = jnp.ones((hd,), jnp.float32)
        p["kn"] = jnp.ones((hd,), jnp.float32)
    return p


def _qkv(p: dict, x: jax.Array, cfg: ModelConfig, positions: jax.Array,
         use_rope: bool = True):
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    if "wqkv" in p:
        qkv = x @ p["wqkv"] + p.get("bqkv", 0)
        q, k, v = jnp.split(qkv, [H * hd, (H + KV) * hd], axis=-1)
        q = q.reshape(B, S, H, hd)
        k = k.reshape(B, S, KV, hd)
        v = v.reshape(B, S, KV, hd)
    else:
        q = (x @ p["wq"] + p.get("bq", 0)).reshape(B, S, H, hd)
        k = (x @ p["wk"] + p.get("bk", 0)).reshape(B, S, KV, hd)
        v = (x @ p["wv"] + p.get("bv", 0)).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["qn"], cfg.norm_eps)
        k = rms_norm(k, p["kn"], cfg.norm_eps)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa_full(q, k, v, causal: bool, q_pos=None, k_pos=None):
    """Materializing attention (small S): q (B,Sq,H,hd), k/v (B,Sk,KV,hd)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    qh = q.reshape(B, Sq, KV, H // KV, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qh, k).astype(jnp.float32)
    scores *= hd ** -0.5
    if causal:
        qp = jnp.arange(Sq) if q_pos is None else q_pos
        kp = jnp.arange(k.shape[1]) if k_pos is None else k_pos
        mask = qp[:, None] >= kp[None, :]
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return out.reshape(B, Sq, H, hd)


def _flash_fwd_impl(q, k, v, chunk: int):
    """Statically-unrolled q blocks, scan over STRICTLY-LOWER kv blocks
    (unmasked) + one static-mask diagonal block. Returns (out, lse).

    O(S) memory, zero FLOPs above the diagonal, and no dynamic mask tensors
    for XLA to hoist into loop carries (which materialized multi-TB pred
    tensors in the first dry-run; EXPERIMENTS.md §Perf 0a).
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    nq = S // chunk
    qb = q.reshape(B, nq, chunk, KV, G, hd)
    kb = jnp.moveaxis(k.reshape(B, nq, chunk, KV, hd), 1, 0)  # (nq,B,c,KV,hd)
    vb = jnp.moveaxis(v.reshape(B, nq, chunk, KV, hd), 1, 0)
    scale = hd ** -0.5
    pos = jnp.arange(chunk)
    diag_mask = (pos[:, None] >= pos[None, :])[None, None, None]  # (1,1,1,c,c)

    def partial_softmax(qc, kc, vc, masked):
        s = jnp.einsum("bqkgh,bskh->bkgqs", qc, kc).astype(jnp.float32)
        s *= scale
        if masked:
            s = jnp.where(diag_mask, s, -1e30)
        m = s.max(-1)
        p = jnp.exp(s - m[..., None])
        l = p.sum(-1)
        acc = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(qc.dtype),
                         vc).astype(jnp.float32)
        return m, l, acc

    def merge(a, b):
        (ma, la, xa), (mb, lb, xb) = a, b
        m = jnp.maximum(ma, mb)
        ca, cb = jnp.exp(ma - m), jnp.exp(mb - m)
        return m, la * ca + lb * cb, xa * ca[..., None] + xb * cb[..., None]

    outs, lses = [], []
    for qi in range(nq):                       # static unroll (nq <= 32)
        qc = qb[:, qi]
        st = partial_softmax(qc, kb[qi], vb[qi], masked=True)   # diagonal
        if qi > 0:
            def kv_step(carry, inp):
                kc, vc = inp
                return merge(carry, partial_softmax(qc, kc, vc, False)), None
            st, _ = jax.lax.scan(kv_step, st, (kb[:qi], vb[:qi]))
        m, l, acc = st
        outs.append(jnp.einsum("bkgqh->bqkgh",
                               acc / l[..., None]).astype(q.dtype))
        lses.append(m + jnp.log(l))            # (B,KV,G,c) fp32
    out = jnp.stack(outs, axis=1).reshape(B, S, H, hd)
    return out, jnp.stack(lses, axis=0)        # lse: (nq,B,KV,G,c)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _sdpa_blockwise(q, k, v, chunk: int):
    """Flash attention with a flash BACKWARD (custom_vjp): the probability
    blocks are recomputed from (q,k,lse) in the backward sweep instead of
    being stashed by autodiff — removes the O(S·c) fp32 p-matrix stashes
    that dominated the memory roofline term (EXPERIMENTS.md §Perf hc3)."""
    out, _ = _flash_fwd_impl(q, k, v, chunk)
    return out


def _sdpa_fwd(q, k, v, chunk):
    out, lse = _flash_fwd_impl(q, k, v, chunk)
    return out, (q, k, v, out, lse)


def _sdpa_bwd(chunk, res, dout):
    q, k, v, out, lse = res
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    nq = S // chunk
    scale = hd ** -0.5
    qb = q.reshape(B, nq, chunk, KV, G, hd)
    dob = dout.reshape(B, nq, chunk, KV, G, hd)
    kb = jnp.moveaxis(k.reshape(B, nq, chunk, KV, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nq, chunk, KV, hd), 1, 0)
    # D_i = rowsum(dO * O) per (query, head) in fp32 -> (nq,B,KV,G,c)
    Dfull = jnp.einsum("bshd,bshd->bsh", dout.astype(jnp.float32),
                       out.astype(jnp.float32))
    Db = Dfull.reshape(B, nq, chunk, KV, G).transpose(1, 0, 3, 4, 2)
    pos = jnp.arange(chunk)
    diag_mask = (pos[:, None] >= pos[None, :])[None, None, None]

    def block_grads(qc, doc, Lc, Dc, kc, vc, masked):
        """One (q-block, kv-block) pair -> (dq_c f32, dk_c f32, dv_c f32)."""
        s = jnp.einsum("bqkgh,bskh->bkgqs", qc, kc).astype(jnp.float32)
        s *= scale
        p = jnp.exp(s - Lc[..., None])                   # (B,KV,G,c,c)
        if masked:
            p = jnp.where(diag_mask, p, 0.0)
        dp = jnp.einsum("bqkgh,bskh->bkgqs", doc, vc).astype(jnp.float32)
        ds = p * (dp - Dc[..., None]) * scale
        dsl = ds.astype(qc.dtype)
        pl = p.astype(qc.dtype)
        dq_c = jnp.einsum("bkgqs,bskh->bqkgh", dsl, kc).astype(jnp.float32)
        dk_c = jnp.einsum("bkgqs,bqkgh->bskh", dsl, qc).astype(jnp.float32)
        dv_c = jnp.einsum("bkgqs,bqkgh->bskh", pl, doc).astype(jnp.float32)
        return dq_c, dk_c, dv_c

    dq = jnp.zeros((B, nq, chunk, KV, G, hd), jnp.float32)
    dk = jnp.zeros((B, S, KV, hd), jnp.float32)
    dv = jnp.zeros((B, S, KV, hd), jnp.float32)
    for qi in range(nq):
        qc, doc = qb[:, qi], dob[:, qi]
        Lc, Dc = lse[qi], Db[qi]
        dq_c, dk_c, dv_c = block_grads(qc, doc, Lc, Dc, kb[qi], vb[qi], True)
        dk = dk.at[:, qi * chunk:(qi + 1) * chunk].add(dk_c)
        dv = dv.at[:, qi * chunk:(qi + 1) * chunk].add(dv_c)
        if qi > 0:
            def kv_step(dq_acc, inp):
                kc, vc = inp
                a, b, c = block_grads(qc, doc, Lc, Dc, kc, vc, False)
                return dq_acc + a, (b, c)
            dq_c, (dks, dvs) = jax.lax.scan(kv_step, dq_c,
                                            (kb[:qi], vb[:qi]))
            # dks: (qi, B, chunk, KV, hd) -> positions [0, qi*chunk)
            dk = dk.at[:, :qi * chunk].add(
                jnp.moveaxis(dks, 0, 1).reshape(B, qi * chunk, KV, hd))
            dv = dv.at[:, :qi * chunk].add(
                jnp.moveaxis(dvs, 0, 1).reshape(B, qi * chunk, KV, hd))
        dq = dq.at[:, qi].set(dq_c)
    return (dq.reshape(B, S, H, hd).astype(q.dtype), dk.astype(k.dtype),
            dv.astype(v.dtype))


_sdpa_blockwise.defvjp(_sdpa_fwd, _sdpa_bwd)


def attention(p: dict, x: jax.Array, cfg: ModelConfig, positions: jax.Array,
              causal: bool = True, kv_override=None) -> jax.Array:
    """Self (or cross, via kv_override=(k,v)) attention over full sequences."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, cfg, positions, use_rope=kv_override is None)
    if kv_override is not None:
        k, v = kv_override
        out = _sdpa_full(q, k, v, causal=False)
    elif causal and S > cfg.attn_chunk and S % cfg.attn_chunk == 0:
        out = _sdpa_blockwise(q, k, v, cfg.attn_chunk)
    else:
        out = _sdpa_full(q, k, v, causal=causal)
    return out.reshape(B, S, cfg.num_heads * cfg.hd) @ p["wo"]


def attention_decode(p: dict, x: jax.Array, cfg: ModelConfig, cache: dict):
    """One-token decode: x (B,1,d); cache {'k','v': (B,Smax,KV,hd), 'idx'}."""
    B = x.shape[0]
    idx = cache["idx"]
    q, k, v = _qkv(p, x, cfg, positions=jnp.full((B, 1), idx))
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, idx, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, idx, 0, 0))
    Smax = ck.shape[1]
    valid = jnp.arange(Smax) <= idx
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    qh = q.reshape(B, KV, H // KV, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qh, ck).astype(jnp.float32) * hd ** -0.5
    s = jnp.where(valid[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgs,bskh->bkgh", w, cv).reshape(B, 1, H * hd)
    return out @ p["wo"], {"k": ck, "v": cv, "idx": idx + 1}


# ------------------------------------------------------------------ mlp ----
def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = cfg.param_dtype
    return {"wg": Init(ks[0], (d, ff), dt), "wu": Init(ks[1], (d, ff), dt),
            "wd": Init(ks[2], (ff, d), dt)}


def mlp(p: dict, x: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])) @ p["wd"]


# ----------------------------------------------------------- embeddings ----
def init_embed(key, cfg: ModelConfig) -> dict:
    V = cfg.padded_vocab
    ks = jax.random.split(key, 2)
    p = {"tok": Init(ks[0], (V, cfg.d_model), cfg.param_dtype)}
    if not cfg.tie_embeddings:
        p["head"] = Init(ks[1], (cfg.d_model, V), cfg.param_dtype)
    return p


def embed(p: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["tok"], tokens, axis=0)


def logits(p: dict, x: jax.Array) -> jax.Array:
    w = p["tok"].T if "head" not in p else p["head"]
    return x @ w


# --------------------------------------------------------------- losses ----
def softmax_xent(lg: jax.Array, labels: jax.Array, z_coef: float = 1e-4):
    """lg: (..., V) logits, labels: (...,) int; -1 is ignored.

    Written as (logsumexp - one_hot.einsum) so GSPMD keeps the vocab dim
    sharded through the reduction (no logits all-gather).
    """
    lg = lg.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    oh = jax.nn.one_hot(labels, lg.shape[-1], dtype=lg.dtype)
    gold = jnp.einsum("...v,...v->...", lg, oh)
    mask = (labels >= 0).astype(jnp.float32)
    nll = (lse - gold) * mask
    z = z_coef * (lse * mask) ** 2
    denom = jnp.maximum(mask.sum(), 1.0)
    return (nll.sum() + z.sum()) / denom
