"""Encoder-decoder model (seamless-m4t): bidirectional encoder over
precomputed frame embeddings (audio frontend stub per spec) + causal decoder
with cross-attention. Both stacks scan over layers."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L
from ..distributed.ctx import constrain_batch

__all__ = ["init_params", "encode", "decode_train", "init_cache",
           "decode_step"]


def _init_enc_layer(key, cfg):
    ks = jax.random.split(key, 2)
    return {"ln1": jnp.ones((cfg.d_model,), jnp.float32),
            "attn": L.init_attention(ks[0], cfg),
            "ln2": jnp.ones((cfg.d_model,), jnp.float32),
            "ff": L.init_mlp(ks[1], cfg)}


def _init_dec_layer(key, cfg):
    ks = jax.random.split(key, 3)
    return {"ln1": jnp.ones((cfg.d_model,), jnp.float32),
            "attn": L.init_attention(ks[0], cfg),
            "lnx": jnp.ones((cfg.d_model,), jnp.float32),
            "xattn": L.init_attention(ks[1], cfg, fused=False),
            "ln2": jnp.ones((cfg.d_model,), jnp.float32),
            "ff": L.init_mlp(ks[2], cfg)}


def init_params(key, cfg: ModelConfig) -> dict:
    ke, k1, k2 = jax.random.split(key, 3)
    enc = jax.vmap(lambda k: _init_enc_layer(k, cfg))(
        jax.random.split(k1, cfg.enc_layers))
    dec = jax.vmap(lambda k: _init_dec_layer(k, cfg))(
        jax.random.split(k2, cfg.num_layers))
    return {"embed": L.init_embed(ke, cfg), "enc": enc, "dec": dec,
            "ln_enc": jnp.ones((cfg.d_model,), jnp.float32),
            "ln_f": jnp.ones((cfg.d_model,), jnp.float32)}


def encode(params: dict, cfg: ModelConfig, frames: jax.Array,
           remat: str = "full") -> jax.Array:
    """frames: (B, S_enc, d_model) precomputed embeddings -> memory."""
    B, S, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x = frames.astype(cfg.param_dtype)

    def body(x, p):
        x = constrain_batch(x)
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        x = x + L.attention(p["attn"], h, cfg, positions, causal=False)
        h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        return x + L.mlp(p["ff"], h), None

    if remat != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc"])
    return L.rms_norm(x, params["ln_enc"], cfg.norm_eps)


def _cross_kv(p, memory, cfg):
    B, Sm, _ = memory.shape
    KV, hd = cfg.num_kv_heads, cfg.hd
    k = (memory @ p["wk"] + p.get("bk", 0)).reshape(B, Sm, KV, hd)
    v = (memory @ p["wv"] + p.get("bv", 0)).reshape(B, Sm, KV, hd)
    return k, v


def decode_train(params: dict, cfg: ModelConfig, tokens: jax.Array,
                 memory: jax.Array, remat: str = "full"):
    """Teacher-forced decoder pass -> hidden states (B, S, d)."""
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x = L.embed(params["embed"], tokens)

    def body(x, p):
        x = constrain_batch(x)
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        x = x + L.attention(p["attn"], h, cfg, positions, causal=True)
        h = L.rms_norm(x, p["lnx"], cfg.norm_eps)
        kv = _cross_kv(p["xattn"], memory, cfg)
        x = x + L.attention(p["xattn"], h, cfg, positions, kv_override=kv)
        h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        return x + L.mlp(p["ff"], h), None

    if remat != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec"])
    return L.rms_norm(x, params["ln_f"], cfg.norm_eps)


def init_cache(params: dict, cfg: ModelConfig, batch: int, max_seq: int,
               memory: jax.Array) -> dict:
    """Self-attn KV cache + precomputed cross K/V per decoder layer."""
    KV, hd = cfg.num_kv_heads, cfg.hd
    Ld = cfg.num_layers
    shape = (Ld, batch, max_seq, KV, hd)
    xk, xv = jax.vmap(lambda p: _cross_kv(p["xattn"], memory, cfg))(
        params["dec"])
    return {"k": jnp.zeros(shape, cfg.param_dtype),
            "v": jnp.zeros(shape, cfg.param_dtype),
            "idx": jnp.zeros((Ld,), jnp.int32), "xk": xk, "xv": xv}


def decode_step(params: dict, cfg: ModelConfig, tokens: jax.Array,
                cache: dict):
    x = L.embed(params["embed"], tokens)

    def body(x, inp):
        p, c = inp
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        y, newc = L.attention_decode(p["attn"], h, cfg,
                                     {"k": c["k"], "v": c["v"],
                                      "idx": c["idx"]})
        x = x + y
        h = L.rms_norm(x, p["lnx"], cfg.norm_eps)
        x = x + L.attention(p["xattn"], h, cfg,
                            positions=jnp.zeros(h.shape[:2], jnp.int32),
                            kv_override=(c["xk"], c["xv"]))
        h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + L.mlp(p["ff"], h)
        return x, {**newc, "xk": c["xk"], "xv": c["xv"]}

    x, newcache = jax.lax.scan(body, x, (params["dec"], cache))
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    return L.logits(params["embed"], x), newcache
