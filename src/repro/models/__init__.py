from .model import ModelBundle, build_model, batch_spec  # noqa: F401
