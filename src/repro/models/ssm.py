"""Mamba-2 block (SSD — state-space duality, arXiv:2405.21060) in JAX.

Recurrence (per head h, state size N, head dim P):
    h_t = exp(a_t) * h_{t-1} + dt_t * B_t x_t^T        h_t: (N, P)
    y_t = C_t @ h_t + D * x_t                          a_t = dt_t * A  (<0)

Training uses the chunked dual form: one lax.scan over chunks of length Q;
inside a chunk the quadratic (Q x Q) form runs on the MXU, across chunks
only the (H, N, P) states flow — the same overlap/boundary-state trick as
the paper's framed Viterbi decoding (DESIGN.md §5). Sub-quadratic in S, so
this is the long_500k path. Decode carries (conv_state, ssm_state).

State math in fp32; projections in cfg.dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import Init, rms_norm

__all__ = ["init_mamba", "mamba_forward", "mamba_decode", "init_mamba_state"]


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.headdim
    return s, d_in, H, s.ngroups, s.d_state


def init_mamba(key, cfg: ModelConfig) -> dict:
    s, d_in, H, G, N = _dims(cfg)
    conv_ch = d_in + 2 * G * N
    ks = jax.random.split(key, 4)
    dt = cfg.param_dtype
    return {
        "in_proj": Init(ks[0], (cfg.d_model, 2 * d_in + 2 * G * N + H), dt),
        "conv_w": Init(ks[1], (s.d_conv, conv_ch), dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.zeros((H,), jnp.float32),          # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_w": jnp.ones((d_in,), jnp.float32),
        "out_proj": Init(ks[3], (d_in, cfg.d_model), dt),
    }


def _split_proj(proj, cfg):
    s, d_in, H, G, N = _dims(cfg)
    z, xBC, dt = jnp.split(proj, [d_in, 2 * d_in + 2 * G * N], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv, width K: (B,S,C) -> (B,S,C)."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def _split_xbc(xBC, cfg):
    s, d_in, H, G, N = _dims(cfg)
    x, Bm, Cm = jnp.split(xBC, [d_in, d_in + G * N], axis=-1)
    B_, S_ = x.shape[0], x.shape[1]
    x = x.reshape(B_, S_, H, s.headdim)
    Bm = Bm.reshape(B_, S_, G, N)
    Cm = Cm.reshape(B_, S_, G, N)
    # broadcast groups to heads
    rep = H // G
    Bm = jnp.repeat(Bm, rep, axis=2)
    Cm = jnp.repeat(Cm, rep, axis=2)
    return x, Bm, Cm


def mamba_forward(p: dict, u: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Training/prefill forward: u (B, S, d_model) -> (B, S, d_model)."""
    s, d_in, H, G, N = _dims(cfg)
    B, S0, _ = u.shape
    Q = min(s.chunk, S0)
    if S0 % Q:                        # causal ⇒ tail padding is harmless
        u = jnp.pad(u, ((0, 0), (0, Q - S0 % Q), (0, 0)))
    S = u.shape[1]
    nc = S // Q

    proj = u @ p["in_proj"]
    z, xBC, dt_raw = _split_proj(proj, cfg)
    xBC = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    x, Bm, Cm = _split_xbc(xBC, cfg)                    # (B,S,H,P),(B,S,H,N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])                            # (H,) negative
    a = dt * A                                          # (B,S,H) log-decay

    # chunked SSD: scan over chunks, carry state (B,H,N,P) -----------------
    P = s.headdim
    xc = x.reshape(B, nc, Q, H, P).astype(jnp.float32)
    Bc = Bm.reshape(B, nc, Q, H, N).astype(jnp.float32)
    Cc = Cm.reshape(B, nc, Q, H, N).astype(jnp.float32)
    ac = a.reshape(B, nc, Q, H)
    dtc = dt.reshape(B, nc, Q, H)
    tri = jnp.tril(jnp.ones((Q, Q), jnp.float32))

    def chunk_step(state, inp):                         # state: (B,H,N,P)
        xq, bq, cq, aq, dq = inp                        # (B,Q,...)
        cs = jnp.cumsum(aq, axis=1)                     # (B,Q,H) inclusive
        # intra-chunk (quadratic, MXU): decay(j->i) = exp(cs_i - cs_j), i>=j
        dec = jnp.exp(cs[:, :, None, :] - cs[:, None, :, :])      # (B,Q,Q,H)
        dec = dec * tri[None, :, :, None]
        cb = jnp.einsum("bihn,bjhn->bijh", cq, bq)
        scores = cb * dec * dq[:, None, :, :]
        y = jnp.einsum("bijh,bjhp->bihp", scores, xq)
        # inter-chunk: contribution of the carried state
        y = y + jnp.einsum("bihn,bhnp->bihp", cq * jnp.exp(cs)[..., None],
                           state)
        # state update: S' = exp(cs_last) S + sum_j exp(cs_last - cs_j) dt_j B_j x_j
        w = jnp.exp(cs[:, -1:, :] - cs) * dq            # (B,Q,H)
        ns = jnp.einsum("bjhn,bjhp->bhnp", bq * w[..., None], xq)
        state = state * jnp.exp(cs[:, -1])[:, :, None, None] + ns
        return state, y

    state0 = jnp.zeros((B, H, N, P), jnp.float32)
    inputs = tuple(jnp.moveaxis(t, 1, 0) for t in (xc, Bc, Cc, ac, dtc))
    _, ys = jax.lax.scan(chunk_step, state0, inputs)    # (nc,B,Q,H,P)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, P)
    y = y + x.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(B, S, d_in).astype(u.dtype)

    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return (y @ p["out_proj"])[:, :S0]


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    s, d_in, H, G, N = _dims(cfg)
    conv_ch = d_in + 2 * G * N
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_ch), cfg.param_dtype),
        "ssm": jnp.zeros((batch, H, N, s.headdim), dtype),
    }


def mamba_decode(p: dict, u: jax.Array, cfg: ModelConfig, state: dict):
    """One-token decode: u (B, 1, d_model); O(1) state, no KV growth."""
    s, d_in, H, G, N = _dims(cfg)
    B = u.shape[0]
    proj = u @ p["in_proj"]
    z, xBC, dt_raw = _split_proj(proj, cfg)             # (B,1,*)
    # conv over (cached d_conv-1 inputs | current)
    hist = jnp.concatenate([state["conv"], xBC.astype(state["conv"].dtype)],
                           axis=1)                      # (B,K,C)
    w, b = p["conv_w"], p["conv_b"]
    conv = jax.nn.silu(jnp.einsum("bkc,kc->bc", hist, w) + b)[:, None, :]
    x, Bm, Cm = _split_xbc(conv, cfg)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])[:, 0]
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A)                                 # (B,H)
    xs = x[:, 0].astype(jnp.float32)                    # (B,H,P)
    Bs = Bm[:, 0].astype(jnp.float32)                   # (B,H,N)
    Cs = Cm[:, 0].astype(jnp.float32)
    ssm = state["ssm"] * a[..., None, None] + jnp.einsum(
        "bhn,bhp->bhnp", Bs * dt[..., None], xs)
    y = jnp.einsum("bhn,bhnp->bhp", Cs, ssm) + xs * p["D"][:, None]
    y = y.reshape(B, 1, d_in).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    new_state = {"conv": hist[:, 1:], "ssm": ssm}
    return y @ p["out_proj"], new_state
