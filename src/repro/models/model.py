"""Unified model API: build_model(cfg) -> ModelBundle.

Every architecture exposes the same four entry points, which is what the
train/serve steps, the dry-run launcher and the smoke tests consume:

    init(rng)                      -> params
    loss(params, batch)            -> scalar   (batch: tokens/labels/+extras)
    prefill(params, batch)         -> (last_logits, cache)
    decode(params, tokens, cache)  -> (logits, cache)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import encdec
from . import layers as L
from . import transformer as T

__all__ = ["ModelBundle", "build_model", "batch_spec"]

AUX_COEF = 0.01


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ModelConfig
    init: Callable
    loss: Callable
    prefill: Callable
    decode: Callable
    init_cache: Callable          # (params, batch, max_seq) -> cache


def _lm_bundle(cfg: ModelConfig, remat: str) -> ModelBundle:
    def init(rng):
        return T.init_params(rng, cfg)

    def loss(params, batch):
        x, aux = T.forward(params, cfg, batch["tokens"],
                           batch.get("vision_embeds"), remat=remat)
        lg = L.logits(params["embed"], x)
        return L.softmax_xent(lg, batch["labels"]) + AUX_COEF * aux

    def prefill(params, batch):
        # forward over the full prompt; emit last-position logits. The KV
        # cache for subsequent decode is built by replaying into
        # init_cache-shaped buffers (structural cost identical).
        x, _ = T.forward(params, cfg, batch["tokens"],
                         batch.get("vision_embeds"), remat=remat)
        lg = L.logits(params["embed"], x[:, -1:])
        return lg

    def init_cache(params, batch_size, max_seq):
        return T.init_cache(cfg, batch_size, max_seq)

    def decode(params, tokens, cache):
        return T.decode_step(params, cfg, tokens, cache)

    return ModelBundle(cfg, init, loss, prefill, decode, init_cache)


def _encdec_bundle(cfg: ModelConfig, remat: str) -> ModelBundle:
    def init(rng):
        return encdec.init_params(rng, cfg)

    def loss(params, batch):
        mem = encdec.encode(params, cfg, batch["frames"], remat=remat)
        x = encdec.decode_train(params, cfg, batch["tokens"], mem,
                                remat=remat)
        lg = L.logits(params["embed"], x)
        return L.softmax_xent(lg, batch["labels"])

    def prefill(params, batch):
        mem = encdec.encode(params, cfg, batch["frames"], remat=remat)
        x = encdec.decode_train(params, cfg, batch["tokens"], mem,
                                remat=remat)
        return L.logits(params["embed"], x[:, -1:])

    def init_cache(params, batch_size, max_seq, memory=None):
        if memory is None:
            memory = jnp.zeros((batch_size, 128, cfg.d_model),
                               cfg.param_dtype)
        return encdec.init_cache(params, cfg, batch_size, max_seq, memory)

    def decode(params, tokens, cache):
        return encdec.decode_step(params, cfg, tokens, cache)

    return ModelBundle(cfg, init, loss, prefill, decode, init_cache)


def build_model(cfg: ModelConfig, remat: str = "full") -> ModelBundle:
    if cfg.family == "encdec":
        return _encdec_bundle(cfg, remat)
    return _lm_bundle(cfg, remat)


def batch_spec(cfg: ModelConfig, seq: int, batch: int, kind: str) -> dict:
    """Abstract input structure for a (cfg, shape) cell — used by both the
    synthetic data pipeline and the dry-run ShapeDtypeStruct specs."""
    if cfg.family == "encdec":
        if kind == "train" or kind == "prefill":
            return {"frames": ((batch, seq, cfg.d_model), jnp.float32),
                    "tokens": ((batch, seq), jnp.int32),
                    "labels": ((batch, seq), jnp.int32)}
        return {"tokens": ((batch, 1), jnp.int32)}
    spec = {"tokens": ((batch, seq if kind != "decode" else 1), jnp.int32)}
    if kind == "train":
        spec["labels"] = ((batch, seq), jnp.int32)
    if cfg.vision_patches and kind in ("train", "prefill"):
        spec["vision_embeds"] = ((batch, cfg.vision_patches, cfg.d_model),
                                 jnp.float32)
    return spec
