"""Decoder-only LM assembly: dense / MoE / SSM / hybrid / VLM.

Scan-over-layers with **superblocks**: the layer pattern (attention-vs-mamba
x dense-vs-MoE) repeats with period SB = lcm(|block_pattern|, moe.period);
layers are stacked as (R = num_layers/SB) repeats and applied with one
lax.scan. HLO size is therefore independent of depth (a 94-layer qwen3-moe
traces one superblock), which keeps the 512-device dry-run compiles fast and
is the remat unit.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L
from .moe import init_moe, moe_ff
from .ssm import init_mamba, init_mamba_state, mamba_decode, mamba_forward
from ..distributed.ctx import constrain_batch

__all__ = ["superblock_kinds", "init_params", "forward", "init_cache",
           "decode_step"]


def superblock_kinds(cfg: ModelConfig) -> list:
    """[(mixer 'A'|'M', ff 'dense'|'moe'|None), ...] for one superblock."""
    pat = cfg.pattern
    period = cfg.moe.period if cfg.moe else 1
    sb = math.lcm(len(cfg.block_pattern), period)
    assert cfg.num_layers % sb == 0, (cfg.num_layers, sb)
    kinds = []
    for i in range(sb):
        if cfg.d_ff == 0 and not cfg.moe_at(i):
            ff = None
        else:
            ff = "moe" if cfg.moe_at(i) else "dense"
        kinds.append((pat[i], ff))
    return kinds


def _init_block(key, cfg: ModelConfig, kind) -> dict:
    mixer, ff = kind
    ks = jax.random.split(key, 2)
    p = {"ln1": jnp.ones((cfg.d_model,), jnp.float32)}
    p["mixer"] = (L.init_attention(ks[0], cfg) if mixer == "A"
                  else init_mamba(ks[0], cfg))
    if ff is not None:
        p["ln2"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["ff"] = init_moe(ks[1], cfg) if ff == "moe" else L.init_mlp(ks[1], cfg)
    return p


def init_params(key, cfg: ModelConfig) -> dict:
    kinds = superblock_kinds(cfg)
    R = cfg.num_layers // len(kinds)
    ke, kb = jax.random.split(key)

    def init_sb(k):
        ks = jax.random.split(k, len(kinds))
        return {f"b{i}": _init_block(ks[i], cfg, kind)
                for i, kind in enumerate(kinds)}

    return {
        "embed": L.init_embed(ke, cfg),
        "blocks": jax.vmap(init_sb)(jax.random.split(kb, R)),
        "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
    }


def _apply_block(p: dict, x: jax.Array, cfg: ModelConfig, kind,
                 positions: jax.Array):
    mixer, ff = kind
    aux = jnp.float32(0)
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    if mixer == "A":
        x = x + L.attention(p["mixer"], h, cfg, positions)
    else:
        x = x + mamba_forward(p["mixer"], h, cfg)
    if ff is not None:
        h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        if ff == "moe":
            y, aux = moe_ff(p["ff"], h, cfg)
            x = x + y
        else:
            x = x + L.mlp(p["ff"], h)
    return x, aux


def forward(params: dict, cfg: ModelConfig, tokens: jax.Array,
            vision_embeds: Optional[jax.Array] = None,
            remat: str = "full"):
    """tokens (B, S) -> (hidden (B, S, d), moe_aux). Train/prefill path."""
    kinds = superblock_kinds(cfg)
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens)
    if cfg.vision_patches and vision_embeds is not None:
        # early fusion: the first vision_patches positions are patch embeds
        Pv = cfg.vision_patches
        x = jnp.concatenate([vision_embeds.astype(x.dtype), x[:, Pv:]], axis=1)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def sb_body(x, sbp):
        x = constrain_batch(x)
        aux = jnp.float32(0)
        for i, kind in enumerate(kinds):
            x, a = _apply_block(sbp[f"b{i}"], x, cfg, kind, positions)
            aux = aux + a
        return x, aux

    if remat == "full":
        sb_body = jax.checkpoint(sb_body)
    elif remat == "dots":
        sb_body = jax.checkpoint(
            sb_body, policy=jax.checkpoint_policies.checkpoint_dots)
    x, auxs = jax.lax.scan(sb_body, x, params["blocks"])
    x = constrain_batch(L.rms_norm(x, params["ln_f"], cfg.norm_eps))
    return x, jnp.sum(auxs)


# ------------------------------------------------------------- decoding ----
def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    """Stacked per-superblock-position caches, leading dim = repeats."""
    kinds = superblock_kinds(cfg)
    R = cfg.num_layers // len(kinds)
    KV, hd = cfg.num_kv_heads, cfg.hd

    def one(kind):
        mixer, _ = kind
        if mixer == "A":
            shape = (R, batch, max_seq, KV, hd)
            return {"k": jnp.zeros(shape, cfg.param_dtype),
                    "v": jnp.zeros(shape, cfg.param_dtype),
                    "idx": jnp.zeros((R,), jnp.int32)}
        st = jax.vmap(lambda _: init_mamba_state(cfg, batch))(jnp.arange(R))
        return st

    return {f"b{i}": one(kind) for i, kind in enumerate(kinds)}


def decode_step(params: dict, cfg: ModelConfig, tokens: jax.Array,
                cache: dict):
    """One-token decode: tokens (B, 1) -> (logits (B, 1, V), new cache)."""
    kinds = superblock_kinds(cfg)
    x = L.embed(params["embed"], tokens)

    def sb_body(x, inp):
        sbp, sbc = inp
        newc = {}
        for i, (mixer, ff) in enumerate(kinds):
            p = sbp[f"b{i}"]
            h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
            if mixer == "A":
                y, newc[f"b{i}"] = L.attention_decode(p["mixer"], h, cfg,
                                                      sbc[f"b{i}"])
            else:
                y, newc[f"b{i}"] = mamba_decode(p["mixer"], h, cfg,
                                                sbc[f"b{i}"])
            x = x + y
            if ff is not None:
                h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
                if ff == "moe":
                    y, _ = moe_ff(p["ff"], h, cfg)
                    x = x + y
                else:
                    x = x + L.mlp(p["ff"], h)
        return x, newc

    # scan over repeats; cache leaves all have leading dim R and the new
    # cache is emitted as the scan output (one slice per repeat)
    x, newcache = jax.lax.scan(sb_body, x, (params["blocks"], cache))
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    lg = L.logits(params["embed"], x)
    return lg, newcache
