"""Lane-wise bit-packing of survivor selectors (paper §IV-B, GPU idiom).

The ACS recursion produces ONE bit of information per (stage, state): the
selector that says which butterfly predecessor survived. The seed kernels
stored that bit in an int32 (unified kernel VMEM scratch) or an int8 (split
kernel's HBM stream), wasting 32x / 8x the footprint. Every GPU Viterbi
decoder in the literature (Peng et al. arXiv:1608.00066; Mohammadidoost &
Hashemi arXiv:2011.13579) packs survivors into machine words; this module
is the TPU/Pallas equivalent.

Two physical layouts, selected by the ``Layout`` enum:

``Layout.LANE`` (the PR-1 layout) packs along the trailing (state = lane)
axis, contiguous — word ``w`` of a packed row holds states ``[32w, 32w+32)``
with state ``s`` at bit ``s % 32``:

    packed[..., s // 32] >> (s % 32) & 1 == sel[..., s]

``Layout.SUBLANE`` is the Mosaic-native variant: the packed-word axis sits
at position -2 (the TPU *sublane* dimension) and the payload axis — frames
in the kernels — stays trailing, on the 128 *lanes*:

    sel (..., S, N)  ->  packed (..., W, N),
    packed[..., s // 32, :] >> (s % 32) & 1 == sel[..., s, :]

On real Mosaic an (8 sublane x 128 lane) tile pads the trailing dim to 128,
so a lane-packed ``(.., W=2)`` array is allocated as if it were 128 wide —
the 32x compression evaporates. Sublane packing puts the tiny W dim where
padding costs at most 8/W and fills the lanes with frames, which is what
makes the compression survive compiled mode (kernels/autotune.py's
``mosaic_padded_bytes`` models exactly this).

All functions are pure jnp on static shapes, so they work identically
inside Pallas kernel bodies (interpret or compiled — XLA folds the shift
table) and at the JAX level (packing the split kernel's HBM stream).
Codes with S < 32 states (e.g. K=5, K=4 test codes) pack into one
zero-padded word — still a win vs S int8s for S > 4.
"""
from __future__ import annotations

import enum

import jax.numpy as jnp

__all__ = ["BITS", "Layout", "packed_width", "pack_bits", "unpack_bits",
           "extract_bit"]

BITS = 32          # word width: int32 is the TPU-native integer lane type


class Layout(str, enum.Enum):
    """Physical placement of the packed-word axis (TPU tiling aware)."""
    LANE = "lane"         # words trailing (lanes): (..., N, W) from (..., N, S)
    SUBLANE = "sublane"   # words at -2 (sublanes): (..., W, N) from (..., S, N)


def packed_width(n: int) -> int:
    """Number of int32 words needed for ``n`` selector bits (>= 1)."""
    return -(-n // BITS)


def _pack_last(sel: jnp.ndarray) -> jnp.ndarray:
    """(..., n) {0,1}-valued -> (..., packed_width(n)) int32 along -1."""
    n = sel.shape[-1]
    w = packed_width(n)
    x = sel.astype(jnp.int32)
    if w * BITS != n:
        pad = [(0, 0)] * (x.ndim - 1) + [(0, w * BITS - n)]
        x = jnp.pad(x, pad)
    x = x.reshape(*x.shape[:-1], w, BITS)
    weights = jnp.left_shift(jnp.int32(1),
                             jnp.arange(BITS, dtype=jnp.int32))
    return jnp.sum(x * weights, axis=-1, dtype=jnp.int32)


def pack_bits(sel: jnp.ndarray, layout: Layout = Layout.LANE) -> jnp.ndarray:
    """Pack selector bits into int32 words.

    LANE:    pack axis -1;  (..., n)    -> (..., w).
    SUBLANE: pack axis -2;  (..., n, N) -> (..., w, N) — the bit axis is the
             second-to-last (sublane) dim, the trailing payload axis (frames
             on lanes) is untouched.

    Bit ``n % 32 == 31`` lands in the int32 sign bit; two's-complement
    wraparound in the weighted sum makes that exact.
    """
    if Layout(layout) is Layout.LANE:
        return _pack_last(sel)
    n = sel.shape[-2]
    w = packed_width(n)
    x = sel.astype(jnp.int32)
    if w * BITS != n:
        pad = [(0, 0)] * (x.ndim - 2) + [(0, w * BITS - n), (0, 0)]
        x = jnp.pad(x, pad)
    x = x.reshape(*x.shape[:-2], w, BITS, x.shape[-1])
    weights = jnp.left_shift(jnp.int32(1),
                             jnp.arange(BITS, dtype=jnp.int32))[:, None]
    return jnp.sum(x * weights, axis=-2, dtype=jnp.int32)


def unpack_bits(packed: jnp.ndarray, n: int,
                layout: Layout = Layout.LANE) -> jnp.ndarray:
    """Inverse of pack_bits for either layout (values in {0, 1})."""
    shifts = jnp.arange(BITS, dtype=jnp.int32)
    if Layout(layout) is Layout.LANE:
        w = packed.shape[-1]
        bits = (packed[..., :, None] >> shifts) & 1      # (..., w, 32)
        return bits.reshape(*packed.shape[:-1], w * BITS)[..., :n]
    w = packed.shape[-2]
    bits = (packed[..., :, None, :] >> shifts[:, None]) & 1  # (..., w, 32, N)
    out = bits.reshape(*packed.shape[:-2], w * BITS, packed.shape[-1])
    return out[..., :n, :]


def extract_bit(packed_row: jnp.ndarray, state: jnp.ndarray,
                layout: Layout = Layout.LANE) -> jnp.ndarray:
    """Selector bit of ``state`` from a packed row.

    LANE:    packed_row (..., w) int32, state (...) broadcast-compatible
             with the leading dims.
    SUBLANE: packed_row (..., w, N) int32, state (..., N) — one lookup per
             trailing lane, words gathered across the sublane axis.

    Uses a word-index one-hot reduction instead of a data-dependent gather
    so it lowers to pure vector ops inside Pallas kernels (mirrors the
    unpacked kernels' one-hot selector extraction). The ``& 1`` after the
    arithmetic shift makes sign-extension of bit-31 words harmless.
    """
    if Layout(layout) is Layout.LANE:
        w = packed_row.shape[-1]
        word_id = state >> 5                             # state // 32
        lanes = jnp.arange(w, dtype=jnp.int32)
        onehot = (word_id[..., None] == lanes).astype(jnp.int32)
        word = jnp.sum(packed_row * onehot, axis=-1)
        return (word >> (state & (BITS - 1))) & 1
    w = packed_row.shape[-2]
    word_id = state >> 5                                 # (..., N)
    subs = jnp.arange(w, dtype=jnp.int32)[:, None]       # (w, 1)
    onehot = (word_id[..., None, :] == subs).astype(jnp.int32)  # (..., w, N)
    word = jnp.sum(packed_row * onehot, axis=-2)
    return (word >> (state & (BITS - 1))) & 1
