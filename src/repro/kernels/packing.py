"""Lane-wise bit-packing of survivor selectors (paper §IV-B, GPU idiom).

The ACS recursion produces ONE bit of information per (stage, state): the
selector that says which butterfly predecessor survived. The seed kernels
stored that bit in an int32 (unified kernel VMEM scratch) or an int8 (split
kernel's HBM stream), wasting 32x / 8x the footprint. Every GPU Viterbi
decoder in the literature (Peng et al. arXiv:1608.00066; Mohammadidoost &
Hashemi arXiv:2011.13579) packs survivors into machine words; this module
is the TPU/Pallas equivalent.

Layout: packing runs along the trailing (state = lane) axis, contiguous —
word ``w`` of a packed row holds states ``[32w, 32w+32)`` with state ``s``
at bit ``s % 32``:

    packed[..., s // 32] >> (s % 32) & 1 == sel[..., s]

Contiguous (not strided) layout keeps the traceback's bit-extract a single
compare-free shift once the word is gathered, and round-trips through
numpy's ``unpackbits`` convention trivially.

All functions are pure jnp on static shapes, so they work identically
inside Pallas kernel bodies (interpret or compiled — XLA folds the shift
table) and at the JAX level (packing the split kernel's HBM stream).
Codes with S < 32 states (e.g. K=5, K=4 test codes) pack into one
zero-padded word — still a win vs S int8s for S > 4.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["BITS", "packed_width", "pack_bits", "unpack_bits", "extract_bit"]

BITS = 32          # word width: int32 is the TPU-native integer lane type


def packed_width(n: int) -> int:
    """Number of int32 words needed for ``n`` selector bits (>= 1)."""
    return -(-n // BITS)


def pack_bits(sel: jnp.ndarray) -> jnp.ndarray:
    """(..., n) {0,1}-valued -> (..., packed_width(n)) int32.

    Bit ``n % 32 == 31`` lands in the int32 sign bit; two's-complement
    wraparound in the weighted sum makes that exact.
    """
    n = sel.shape[-1]
    w = packed_width(n)
    x = sel.astype(jnp.int32)
    if w * BITS != n:
        pad = [(0, 0)] * (x.ndim - 1) + [(0, w * BITS - n)]
        x = jnp.pad(x, pad)
    x = x.reshape(*x.shape[:-1], w, BITS)
    weights = jnp.left_shift(jnp.int32(1),
                             jnp.arange(BITS, dtype=jnp.int32))
    return jnp.sum(x * weights, axis=-1, dtype=jnp.int32)


def unpack_bits(packed: jnp.ndarray, n: int) -> jnp.ndarray:
    """(..., w) int32 -> (..., n) int32 of {0,1}; inverse of pack_bits."""
    w = packed.shape[-1]
    shifts = jnp.arange(BITS, dtype=jnp.int32)
    bits = (packed[..., :, None] >> shifts) & 1      # (..., w, 32)
    return bits.reshape(*packed.shape[:-1], w * BITS)[..., :n]


def extract_bit(packed_row: jnp.ndarray, state: jnp.ndarray) -> jnp.ndarray:
    """Selector bit of ``state`` from a packed row.

    packed_row: (..., w) int32 packed selectors for one trellis stage.
    state:      (...) int32 state index, broadcast-compatible with the
                leading dims of ``packed_row``.

    Uses a word-index one-hot reduction instead of a data-dependent gather
    so it lowers to pure vector ops inside Pallas kernels (mirrors the
    unpacked kernels' one-hot selector extraction). The ``& 1`` after the
    arithmetic shift makes sign-extension of bit-31 words harmless.
    """
    w = packed_row.shape[-1]
    word_id = state >> 5                             # state // 32
    lanes = jnp.arange(w, dtype=jnp.int32)
    onehot = (word_id[..., None] == lanes).astype(jnp.int32)
    word = jnp.sum(packed_row * onehot, axis=-1)
    return (word >> (state & (BITS - 1))) & 1
