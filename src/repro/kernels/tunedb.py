"""Disk-backed measured-autotune database for decode plans.

``plan_decode`` is an analytic VMEM model: it predicts which kernel
configuration *should* be fastest from a byte-accounting of the per-tile
working set. That model ranks configurations well in interpret mode, but
the paper's regime is real hardware, where DMA pipelining, lane padding,
and compiler scheduling decide the winner — the only honest arbiter is a
timed launch on the device that will actually run the plan.

Measuring is expensive (a compile plus several launches per candidate),
so measurements are cached HERE, on disk, keyed by::

    DecodePlan.fingerprint()  x  platform identity

where the platform identity is the same (backend, device_kind,
jax_version) stamp ``benchmarks/trajectory.platform()`` puts on every
recorded benchmark run — ``platform_id`` below is the single source of
truth both import. A plan is therefore measured once per (hardware,
code) pair and the result is shared by every process on the machine:
the serve layer, the stream front-end, and the benchmarks all converge
on the same measured choice without re-paying the timing pass.

Robustness contract (the acceptance criterion of the observatory PR):

  * a second process with the same fingerprint + platform reuses the
    cached timing — zero re-measurement, visible as ``tunedb_hits``
    tracer counters and ``TuneDB.stats()``;
  * a changed fingerprint (any plan knob) or a different device kind
    misses and re-measures;
  * a corrupt/truncated/wrong-schema DB file is DISCARDED with a
    structured ``TuneDBWarning`` — never a crash, never a half-loaded
    table; the next ``put`` rewrites a clean file;
  * writes are atomic (tmp + fsync + ``os.replace``) and merge with
    whatever is on disk first, so concurrent processes appending
    different plans never clobber each other's rows.

The DB location is ``$REPRO_TUNE_DB`` when set, else
``~/.cache/repro_viterbi/tunedb.json`` (``default_path``). Delete the
file — or point the env var elsewhere — to invalidate every measurement
(e.g. after a driver/toolchain upgrade the jax_version key does not
capture).
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
import time
import warnings

from ..obs.tracer import get_tracer

__all__ = ["TuneDB", "TUNE_DB", "TuneDBWarning", "platform_id",
           "platform_key", "default_path", "SCHEMA"]

SCHEMA = "repro.tunedb/v1"

#: Env var overriding the DB file location (tests point it at a tmp dir;
#: ops point it at shared fast storage).
ENV_PATH = "REPRO_TUNE_DB"


class TuneDBWarning(UserWarning):
    """A tune-DB file could not be used (corrupt / wrong schema) and was
    discarded. Structured so callers and test suites can filter on it —
    the decode path itself must never crash on a bad cache file."""


def platform_id() -> dict:
    """The JAX backend/device identity of THIS process — the hardware
    half of every tune-DB key, and the stamp ``benchmarks/trajectory``
    puts on recorded runs (it delegates here). Lazy jax import: loading
    the DB module must not initialize JAX."""
    import jax
    return {"backend": jax.default_backend(),
            "device_kind": jax.devices()[0].device_kind,
            "jax_version": jax.__version__}


def platform_key(platform: dict | None = None) -> str:
    """Flatten a platform identity into the string the DB is keyed by.
    ``jax_version`` is part of the key: a toolchain upgrade recompiles
    every kernel, so old timings must not be trusted across it."""
    p = platform or platform_id()
    return f"{p['backend']}/{p['device_kind']}/{p.get('jax_version', '?')}"


def default_path() -> str:
    env = os.environ.get(ENV_PATH)
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro_viterbi",
                        "tunedb.json")


class TuneDB:
    """Thread-safe, process-shared table of measured plan timings.

    Rows live under ``data[platform_key][fingerprint]`` and are plain
    JSON dicts (``ms``/``mbps``/``frames``/``reps``/``measured_at`` plus
    whatever the measuring pass records). ``get`` counts hits/misses on
    the instance and on the process tracer (``tunedb_hits`` /
    ``tunedb_misses``) so a trace file alone shows whether a run
    re-measured; ``record_measure`` counts actual timing passes
    (``tunedb_measures``) — the acceptance criterion's "zero
    re-measurement in a second process" is literally
    ``stats()['measures'] == 0``.
    """

    def __init__(self, path: str | None = None):
        self._path = path
        self._lock = threading.Lock()
        self._data: dict | None = None      # lazy: load on first access
        self.hits = 0
        self.misses = 0
        self.measures = 0

    @property
    def path(self) -> str:
        return self._path or default_path()

    # -- disk ------------------------------------------------------------
    def _read_file(self) -> dict:
        """Parse the on-disk table; a missing file is empty, a BAD file
        is a TuneDBWarning + empty (the robustness contract)."""
        path = self.path
        if not os.path.exists(path):
            return {}
        try:
            with open(path) as fh:
                doc = json.load(fh)
            if not isinstance(doc, dict) or doc.get("schema") != SCHEMA:
                raise ValueError(
                    f"schema is {doc.get('schema')!r} (expected {SCHEMA!r})"
                    if isinstance(doc, dict) else
                    f"document is {type(doc).__name__}, expected an object")
            table = doc.get("platforms", {})
            if not isinstance(table, dict) or not all(
                    isinstance(v, dict) for v in table.values()):
                raise ValueError("'platforms' is not a table of tables")
            return table
        except (OSError, ValueError, TypeError) as e:
            warnings.warn(
                f"tune DB at {path} is unusable ({e.__class__.__name__}: "
                f"{e}); discarding it — plans will be re-measured and the "
                f"next write replaces the file", TuneDBWarning,
                stacklevel=3)
            return {}

    def _write_file(self, table: dict) -> None:
        """Atomic tmp + fsync + replace, so a reader (or a crash) never
        sees a torn table."""
        path = self.path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                                   prefix=".tunedb-")
        try:
            with os.fdopen(fd, "w") as fh:
                json.dump({"schema": SCHEMA, "platforms": table}, fh,
                          indent=1, sort_keys=True)
                fh.write("\n")
                fh.flush()
                os.fsync(fh.fileno())
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def _table(self) -> dict:
        if self._data is None:
            self._data = self._read_file()
        return self._data

    # -- API -------------------------------------------------------------
    def get(self, fingerprint: str, platform: dict | None = None) -> dict | None:
        """The measured record for (plan, platform), or None. Bumps the
        hit/miss counters here and on the process tracer."""
        key = platform_key(platform)
        with self._lock:
            rec = self._table().get(key, {}).get(fingerprint)
            if rec is not None:
                self.hits += 1
            else:
                self.misses += 1
        get_tracer().count("tunedb_hits" if rec is not None
                           else "tunedb_misses")
        return rec

    def put(self, fingerprint: str, record: dict,
            platform: dict | None = None) -> dict:
        """Persist one measured record, merging with whatever is on disk
        first so concurrent writers keep each other's rows. Returns the
        stored record."""
        key = platform_key(platform)
        record = dict(record)
        record.setdefault("measured_at", time.time())
        with self._lock:
            table = self._read_file()       # fresh merge base
            mem = self._data or {}
            for pk, rows in mem.items():    # keep rows only we have seen
                table.setdefault(pk, {}).update(
                    {fp: r for fp, r in rows.items()
                     if fp not in table.get(pk, {})})
            table.setdefault(key, {})[fingerprint] = record
            self._write_file(table)
            self._data = table
        return record

    def record_measure(self, n: int = 1) -> None:
        """Count a real timing pass (the expensive thing the DB avoids)."""
        with self._lock:
            self.measures += n
        get_tracer().count("tunedb_measures", n)

    def invalidate(self) -> None:
        """Drop the in-memory table AND delete the on-disk file — the
        runbook's 'measurements are stale' escape hatch."""
        with self._lock:
            self._data = {}
            try:
                os.unlink(self.path)
            except OSError:
                pass

    def stats(self) -> dict:
        with self._lock:
            table = self._table()
            return {"path": self.path,
                    "platforms": len(table),
                    "entries": sum(len(v) for v in table.values()),
                    "hits": self.hits, "misses": self.misses,
                    "measures": self.measures}


#: Process-global default instance (``plan_decode(measure=True)`` uses it
#: unless handed another). Lazy: nothing is read until the first lookup.
TUNE_DB = TuneDB()
