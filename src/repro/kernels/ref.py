"""Pure-jnp oracles for the Pallas kernels.

Deliberately written on top of the already-unit-tested ``repro.core``
reference algorithms (which are themselves validated against the encoder
round-trip and a hand-written numpy encoder), so kernel == ref == Alg. 1+2.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..core.decoder import viterbi_forward
from ..core.framed import FrameSpec, decode_frame
from ..core.trellis import Trellis

__all__ = ["unified_decode_frames_ref", "forward_frames_ref"]


@partial(jax.jit, static_argnums=(1, 2))
def unified_decode_frames_ref(frames: jax.Array, trellis: Trellis,
                              spec: FrameSpec) -> jax.Array:
    """(F, L, beta) -> (F, f) bits; oracle for viterbi_unified."""
    return jax.vmap(lambda fr: decode_frame(fr, trellis, spec))(frames)


@partial(jax.jit, static_argnums=(1,))
def forward_frames_ref(frames: jax.Array, trellis: Trellis):
    """(F, L, beta) -> (sel (F,L,S) int8, amax (F,L)); oracle for viterbi_fwd."""
    def one(fr):
        sel, _, amax = viterbi_forward(fr, trellis)
        return sel.astype(jnp.int8), amax
    return jax.vmap(one)(frames)
