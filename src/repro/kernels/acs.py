"""Shared ACS scan body for the Pallas Viterbi kernels.

Both kernels (viterbi_unified, viterbi_fwd) run the identical forward
recursion — coalesced branch metrics, then the add-compare-select scan at
radix 2 or 4 — and differ only in where the survivor selectors go (VMEM
scratch vs HBM stream). ``acs_scan`` factors that recursion into one
place, parameterized by a ``store(t, sel, sigma)`` callback, so a change
to the tie-break / normalization / radix-4 pair ordering cannot drift
between the two kernels and silently break their bit-exactness.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.trellis import Trellis
from .tables import kernel_tables, radix4_tables

__all__ = ["acs_scan"]


def acs_scan(llr_ref, bm_ref, *, trellis: Trellis, L: int, radix: int, store):
    """Branch metrics + ACS over all L stages; returns the final sigma.

    llr_ref: (FT, L, beta) kernel input ref.
    bm_ref:  (L, FT, 2^(beta-1)) VMEM scratch, filled with the
             symmetry-compressed branch metrics (paper Fig. 7 / eq. 9).
    store:   callback invoked once per stage, in stage order, with
             (t, sel (FT, S) bool, sigma (FT, S) normalized) — writes the
             survivors wherever the calling kernel keeps them.

    radix=4 fuses two stages per scan step via the fused BM indexing of
    ``radix4_tables`` — half the trip count, bit-identical arithmetic
    (each half-step is the exact radix-2 sequence incl. normalization).
    """
    S = trellis.num_states
    FT = llr_ref.shape[0]
    if radix == 4:
        perm, idx2, sgn2, signs_half = radix4_tables(trellis)
    else:
        perm, idx_p, sgn_p, signs_half = kernel_tables(trellis)
        idx2, sgn2 = [idx_p], [sgn_p]

    # coalesced, symmetry-compressed branch metrics into VMEM
    llr = llr_ref[...].astype(jnp.float32)           # (FT, L, beta)
    bm_ref[...] = jnp.einsum("flb,hb->lfh", llr, signs_half)

    def acs_half(sigma, bmrow, st):                  # one radix-2 half-step
        cand = []
        for p in (0, 1):
            s_prev = jnp.take(sigma, perm[p], axis=1)              # (FT, S)
            bm = jnp.take(bmrow, idx2[st][p], axis=1) * sgn2[st][p]
            cand.append(s_prev + bm)
        sel = (cand[1] >= cand[0])                   # ties -> i'' (Alg. 1)
        sigma = jnp.where(sel, cand[1], cand[0])
        sigma = sigma - jnp.max(sigma, axis=1, keepdims=True)      # normalize
        return sigma, sel

    sigma0 = jnp.zeros((FT, S), jnp.float32)
    if radix == 4:
        def acs_pair(t2, sigma):
            t = 2 * t2
            bm2 = jnp.concatenate([bm_ref[t], bm_ref[t + 1]], axis=1)
            for st in (0, 1):                        # exact radix-2 order
                sigma, sel = acs_half(sigma, bm2, st)
                store(t + st, sel, sigma)
            return sigma
        sigma = jax.lax.fori_loop(0, L // 2, acs_pair, sigma0)
        if L % 2:                                    # odd-length tail stage
            sigma, sel = acs_half(sigma, bm_ref[L - 1], 0)
            store(L - 1, sel, sigma)
        return sigma

    def acs_step(t, sigma):
        sigma, sel = acs_half(sigma, bm_ref[t], 0)
        store(t, sel, sigma)
        return sigma
    return jax.lax.fori_loop(0, L, acs_step, sigma0)
