"""Shared ACS scan body for the Pallas Viterbi kernels.

Both kernels (viterbi_unified, viterbi_fwd) run the identical forward
recursion — coalesced branch metrics, then the add-compare-select scan at
radix 2 or 4 — and differ only in where the survivor selectors go (VMEM
scratch vs HBM stream). ``acs_scan`` factors that recursion into one
place, parameterized by a ``store(t, sel, sigma)`` callback, so a change
to the tie-break / normalization / radix-4 pair ordering cannot drift
between the two kernels and silently break their bit-exactness.

Layouts (kernels/packing.Layout):
  * LANE    — the PR-1 orientation: working arrays are (FT, S), frames on
    sublanes, states on lanes; bm scratch is (L, FT, half).
  * SUBLANE — Mosaic-native: the whole recursion runs transposed, (S, FT)
    with frames on lanes, and the bm scratch is the FLAT 2D array
    (L * half, FT) — flattening stages into the sublane axis avoids the
    8-sublane padding a (L, half, FT) scratch would pay on the tiny
    ``half`` dim. Stage t lives at rows [t*half, (t+1)*half). Both
    orientations perform the identical arithmetic sequence (elementwise
    adds/selects, exact max reductions, same gather tables), so they are
    bit-identical for float32 branch metrics.

``bm_dtype`` sets the *storage* dtype of the compressed branch metrics
(eq. 9): float32, or bfloat16 to halve the second-largest VMEM term. Path
metrics always accumulate in float32 — BMs are rounded once on store and
cast back up before the add, so bf16 costs one quantization of the inputs,
not a lossy recursion (tests/test_ber.py bounds the BER delta).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.trellis import Trellis
from .packing import Layout
from .tables import kernel_tables, radix4_tables

__all__ = ["acs_scan"]


def acs_scan(llr_ref, bm_ref, *, trellis: Trellis, L: int, radix: int, store,
             layout: Layout = Layout.LANE, bm_dtype=jnp.float32):
    """Branch metrics + ACS over all L stages; returns the final sigma.

    llr_ref: (FT, L, beta) kernel input ref, or the flattened (FT, L*beta)
             block the SUBLANE layout uses (lane-padding-friendly).
    bm_ref:  VMEM scratch for the symmetry-compressed branch metrics
             (paper Fig. 7 / eq. 9): (L, FT, half) for LANE, flat
             (L*half, FT) for SUBLANE; dtype ``bm_dtype``.
    store:   callback invoked once per stage, in stage order, with
             (t, sel, sigma) — sel/sigma are (FT, S) in LANE orientation
             and (S, FT) in SUBLANE orientation; writes the survivors
             wherever the calling kernel keeps them.

    radix=4 fuses two stages per scan step via the fused BM indexing of
    ``radix4_tables`` — half the trip count, bit-identical arithmetic
    (each half-step is the exact radix-2 sequence incl. normalization).
    """
    S = trellis.num_states
    beta = trellis.beta
    half = 1 << (beta - 1)
    FT = llr_ref.shape[0]
    sub = Layout(layout) is Layout.SUBLANE
    if radix == 4:
        perm, idx2, sgn2, signs_half = radix4_tables(trellis)
    else:
        perm, idx_p, sgn_p, signs_half = kernel_tables(trellis)
        idx2, sgn2 = [idx_p], [sgn_p]

    # coalesced, symmetry-compressed branch metrics into VMEM
    llr = llr_ref[...].astype(jnp.float32)
    if llr.ndim == 2:                                # SUBLANE flat block
        llr = llr.reshape(FT, L, beta)
    if sub:
        bm = jnp.einsum("flb,hb->lhf", llr, signs_half)   # (L, half, FT)
        bm_ref[...] = bm.reshape(L * half, FT).astype(bm_dtype)
        bmrow = lambda t, k=1: bm_ref[pl.ds(t * half, k * half)]
    else:
        bm_ref[...] = jnp.einsum("flb,hb->lfh", llr,
                                 signs_half).astype(bm_dtype)
        bmrow = lambda t, k=1: (bm_ref[t] if k == 1 else
                                jnp.concatenate([bm_ref[t], bm_ref[t + 1]],
                                                axis=1))

    def acs_half(sigma, bmr, st):                    # one radix-2 half-step
        cand = []
        for p in (0, 1):
            if sub:                                  # states on sublanes
                s_prev = jnp.take(sigma, perm[p], axis=0)          # (S, FT)
                bm = (jnp.take(bmr, idx2[st][p], axis=0)
                      .astype(jnp.float32) * sgn2[st][p][:, None])
            else:                                    # states on lanes
                s_prev = jnp.take(sigma, perm[p], axis=1)          # (FT, S)
                bm = (jnp.take(bmr, idx2[st][p], axis=1)
                      .astype(jnp.float32) * sgn2[st][p])
            cand.append(s_prev + bm)
        sel = (cand[1] >= cand[0])                   # ties -> i'' (Alg. 1)
        sigma = jnp.where(sel, cand[1], cand[0])
        sigma = sigma - jnp.max(sigma, axis=0 if sub else 1,
                                keepdims=True)       # normalize
        return sigma, sel

    sigma0 = jnp.zeros((S, FT) if sub else (FT, S), jnp.float32)
    if radix == 4:
        def acs_pair(t2, sigma):
            t = 2 * t2
            bm2 = bmrow(t, 2)             # both stages' rows, fused indexing
            for st in (0, 1):                        # exact radix-2 order
                sigma, sel = acs_half(sigma, bm2, st)
                store(t + st, sel, sigma)
            return sigma
        sigma = jax.lax.fori_loop(0, L // 2, acs_pair, sigma0)
        if L % 2:                                    # odd-length tail stage
            sigma, sel = acs_half(sigma, bmrow(L - 1), 0)
            store(L - 1, sel, sigma)
        return sigma

    def acs_step(t, sigma):
        sigma, sel = acs_half(sigma, bmrow(t), 0)
        store(t, sel, sigma)
        return sigma
    return jax.lax.fori_loop(0, L, acs_step, sigma0)
