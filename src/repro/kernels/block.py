"""Intra-frame block-parallel decode: policy + geometry for the kernels.

All other parallelism in this package is *across* frames — each frame's
L-stage ACS scan is still a sequential ``fori_loop``, so a long frame
bounds kernel throughput and serve window latency no matter how many
frames a tile holds. The block-based Gb/s decoder (arXiv 1608.00066)
removes that bound: split one frame's f kept stages into ``block_frames``
independent blocks of ``f/B`` stages, give every block an ``overlap``-
stage *training* region on the left (ACS warm-up from a uniform metric,
exactly like the frame's own v1) and *truncation* region on the right
(traceback convergence, like v2), decode the blocks in parallel, and drop
the overlap regions at merge. Blocks are just shorter frames laid out on
the existing frame axis, so the unchanged unified/split kernels decode
them — one long frame fills a tile the way many short frames do today,
the per-tile scan shrinks from ``v1+f+v2`` to ``f/B + 2*overlap`` stages,
and the bit-packed survivor machinery works as-is in both layouts.

Accuracy is the standard truncated-traceback trade-off: with ``overlap``
at least ~5 constraint lengths the survivor paths have converged and the
BER penalty is below the 1e-3 gate (tests/test_block.py, ci.sh block
smoke). Two exactness regimes anchor the tests:

* ``overlap <= min(v1, v2)``: every block window lies inside its frame's
  real data, so the blocked decode is bit-identical to re-framing the
  stream with ``spec.blocked(B, overlap)`` (fine-framing equivalence).
* ``overlap >= full_overlap(spec, B)``: every block window covers the
  whole frame, warm-up and truncation degenerate away, and the decode is
  bit-identical to the unblocked frame decode (the degenerate gate).

The geometry primitives (``FrameSpec.blocked``, ``reframe_blocks``,
``merge_blocks``) live in core/framed.py next to ``frame_llr``; this
module adds the planner-facing policy: default truncation depth, the
auto block count, and the ``resolve_block`` entry ``autotune.plan_decode``
and ``core.pipeline`` share.
"""
from __future__ import annotations

from ..core.framed import (FrameSpec, merge_blocks,  # noqa: F401 (re-export)
                           reframe_blocks)
from ..core.trellis import Trellis

__all__ = ["BLOCK_LEN_THRESHOLD", "TRUNCATION_DEPTH_MULT", "default_overlap",
           "full_overlap", "choose_block_frames", "resolve_block",
           "reframe_blocks", "merge_blocks"]

#: Kept stages per frame below which the ``"auto"`` policy leaves blocking
#: off: short frames already fill tiles across the frame axis, and the
#: 2*overlap training/truncation tax (~70 stages at K=7) would dominate.
BLOCK_LEN_THRESHOLD = 1024

#: Default truncation depth in constraint lengths. ~5*K is the classic
#: rule of thumb for truncated Viterbi traceback: survivor paths merge
#: with overwhelming probability within that window, putting the BER
#: penalty well under the 1e-3 gate.
TRUNCATION_DEPTH_MULT = 5


def default_overlap(trellis: Trellis, spec: FrameSpec | None = None) -> int:
    """The ~5*K truncation-depth default, widened to cover a parallel-
    traceback spec's v2s (the derived block spec needs v2s <= overlap)."""
    ov = TRUNCATION_DEPTH_MULT * trellis.k
    if spec is not None and spec.parallel_tb:
        ov = max(ov, spec.v2s)
    return ov


def full_overlap(spec: FrameSpec, block_frames: int) -> int:
    """Smallest overlap at which EVERY block's window covers the whole
    frame — the degenerate regime where blocking is bit-identical to the
    unblocked decode (block b spans ``[v1 + b*fb - ov, v1+(b+1)*fb + ov)``;
    the last block needs ``ov >= v1 + (B-1)*fb`` to reach stage 0, the
    first needs ``ov >= v2 + (B-1)*fb`` to reach the frame end)."""
    B = int(block_frames)
    if spec.f % B != 0:
        raise ValueError(f"f={spec.f} is not a multiple of "
                         f"block_frames={B}")
    return (B - 1) * (spec.f // B) + max(spec.v1, spec.v2)


def choose_block_frames(spec: FrameSpec, overlap: int) -> int:
    """Largest block count that divides f, keeps the block body at least
    twice the overlap (so the training/truncation tax stays under ~50% of
    the scan), and preserves a parallel-traceback geometry (f0 | block).
    Returns 1 when no usable split exists."""
    ov = int(overlap)
    for B in range(spec.f, 1, -1):
        if spec.f % B != 0:
            continue
        fb = spec.f // B
        if fb < max(1, 2 * ov):
            continue
        if spec.parallel_tb and fb % spec.f0 != 0:
            continue
        return B
    return 1


def resolve_block(trellis: Trellis, spec: FrameSpec,
                  block_frames: int | str = 1,
                  overlap: int | None = None) -> tuple[int, int]:
    """Resolve the user-facing (block_frames, overlap) knobs to concrete
    ints: ``(1, 0)`` means blocking is off. ``block_frames`` may be an
    explicit count (validated against the spec), or ``"auto"`` — engage
    only past BLOCK_LEN_THRESHOLD kept stages, with ``choose_block_frames``
    picking the split. ``overlap=None`` takes the ~5*K default."""
    if block_frames in (None, 0, 1):
        return 1, 0
    ov = default_overlap(trellis, spec) if overlap is None else int(overlap)
    if block_frames == "auto":
        if spec.f < BLOCK_LEN_THRESHOLD:
            return 1, 0
        B = choose_block_frames(spec, ov)
        if B == 1:
            return 1, 0
    else:
        B = int(block_frames)
    spec.blocked(B, ov)                     # validate the derived geometry
    return B, ov
