"""Forward-only Viterbi kernel — the prior-work baseline (Table I row b).

Same ACS as the unified kernel, but the survivor selectors are STREAMED TO
HBM (the GPU papers' "global memory") and traced back by a separate step.
Exists so the unified kernel's memory-traffic win is measurable:
  survivor-path HBM traffic here = F * L * S * 1 byte  (written then re-read)
  survivor-path HBM traffic in the unified kernel = 0.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.trellis import Trellis
from .tables import kernel_tables

__all__ = ["forward_frames"]


def _kernel(llr_ref, sel_ref, amax_ref, bm_ref, *, trellis: Trellis, L: int):
    S = trellis.num_states
    FT = llr_ref.shape[0]
    perm, idx_p, sgn_p, signs_half = kernel_tables(trellis)

    llr = llr_ref[...].astype(jnp.float32)
    bm_ref[...] = jnp.einsum("flb,hb->lfh", llr, signs_half)

    def acs_step(t, sigma):
        bmh = bm_ref[t]
        cand = []
        for p in (0, 1):
            s_prev = jnp.take(sigma, perm[p], axis=1)
            bm = jnp.take(bmh, idx_p[p], axis=1) * sgn_p[p]
            cand.append(s_prev + bm)
        sel = (cand[1] >= cand[0])
        sigma = jnp.where(sel, cand[1], cand[0])
        sigma = sigma - jnp.max(sigma, axis=1, keepdims=True)
        sel_ref[:, t, :] = sel.astype(jnp.int8)      # -> HBM-backed output
        amax_ref[:, t] = jnp.argmax(sigma, axis=1).astype(jnp.int32)
        return sigma

    jax.lax.fori_loop(0, L, acs_step, jnp.zeros((FT, S), jnp.float32))


@functools.partial(jax.jit, static_argnames=("trellis", "frames_per_tile",
                                             "interpret"))
def forward_frames(frames: jax.Array, *, trellis: Trellis,
                   frames_per_tile: int = 8, interpret: bool = True):
    """(F, L, beta) llr -> (sel (F, L, S) int8, amax (F, L) int32) in HBM."""
    F, L, beta = frames.shape
    FT = frames_per_tile
    assert F % FT == 0, (F, FT)
    S = trellis.num_states
    half = 1 << (trellis.beta - 1)

    kern = functools.partial(_kernel, trellis=trellis, L=L)
    return pl.pallas_call(
        kern,
        grid=(F // FT,),
        in_specs=[pl.BlockSpec((FT, L, beta), lambda i: (i, 0, 0))],
        out_specs=[pl.BlockSpec((FT, L, S), lambda i: (i, 0, 0)),
                   pl.BlockSpec((FT, L), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((F, L, S), jnp.int8),
                   jax.ShapeDtypeStruct((F, L), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((L, FT, half), jnp.float32)],
        interpret=interpret,
    )(frames)
