"""Forward-only Viterbi kernel — the prior-work baseline (Table I row b).

Same ACS as the unified kernel, but the survivor selectors are STREAMED TO
HBM (the GPU papers' "global memory") and traced back by a separate step.
Exists so the unified kernel's memory-traffic win is measurable:
  survivor-path HBM traffic here = F * L * S * 1 byte  (written then re-read)
  survivor-path HBM traffic in the unified kernel = 0.

``pack_survivors`` bit-packs the streamed selectors into int32 words
(kernels/packing.py): F * L * ceil(S/32) * 4 bytes on the wire — 8x less
than the int8 stream — which keeps the split-vs-unified comparison honest
once the unified kernel packs its VMEM scratch. ``radix=4`` fuses two
trellis stages per scan step (see tables.radix4_tables); both knobs are
bit-exact vs the radix-2 / unpacked seed kernel.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.trellis import Trellis
from .acs import acs_scan
from .packing import pack_bits, packed_width

__all__ = ["forward_frames"]


def _kernel(llr_ref, sel_ref, amax_ref, bm_ref, *, trellis: Trellis, L: int,
            pack: bool, radix: int):
    # same forward recursion as the unified kernel (shared via acs.py);
    # only the survivor destination differs: HBM-backed output refs.
    def store(t, sel, sigma):
        if pack:
            sel_ref[:, t, :] = pack_bits(sel)        # -> HBM, 1 bit/state
        else:
            sel_ref[:, t, :] = sel.astype(jnp.int8)  # -> HBM, 1 byte/state
        amax_ref[:, t] = jnp.argmax(sigma, axis=1).astype(jnp.int32)

    acs_scan(llr_ref, bm_ref, trellis=trellis, L=L, radix=radix, store=store)


@functools.partial(jax.jit, static_argnames=(
    "trellis", "frames_per_tile", "pack_survivors", "radix", "interpret"))
def forward_frames(frames: jax.Array, *, trellis: Trellis,
                   frames_per_tile: int = 8, pack_survivors: bool = False,
                   radix: int = 2, interpret: bool = True):
    """(F, L, beta) llr -> (sel, amax (F, L) int32) in HBM.

    sel is (F, L, S) int8, or (F, L, ceil(S/32)) int32 when packed.
    """
    F, L, beta = frames.shape
    FT = frames_per_tile
    assert F % FT == 0, (F, FT)
    assert radix in (2, 4), radix
    S = trellis.num_states
    half = 1 << (trellis.beta - 1)
    sel_w = packed_width(S) if pack_survivors else S
    sel_dt = jnp.int32 if pack_survivors else jnp.int8

    kern = functools.partial(_kernel, trellis=trellis, L=L,
                             pack=pack_survivors, radix=radix)
    return pl.pallas_call(
        kern,
        grid=(F // FT,),
        in_specs=[pl.BlockSpec((FT, L, beta), lambda i: (i, 0, 0))],
        out_specs=[pl.BlockSpec((FT, L, sel_w), lambda i: (i, 0, 0)),
                   pl.BlockSpec((FT, L), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((F, L, sel_w), sel_dt),
                   jax.ShapeDtypeStruct((F, L), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((L, FT, half), jnp.float32)],
        interpret=interpret,
    )(frames)
