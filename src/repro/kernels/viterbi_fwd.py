"""Forward-only Viterbi kernel — the prior-work baseline (Table I row b).

Same ACS as the unified kernel, but the survivor selectors are STREAMED TO
HBM (the GPU papers' "global memory") and traced back by a separate step.
Exists so the unified kernel's memory-traffic win is measurable:
  survivor-path HBM traffic here = F * L * S * 1 byte  (written then re-read)
  survivor-path HBM traffic in the unified kernel = 0.

``pack_survivors`` bit-packs the streamed selectors into int32 words
(kernels/packing.py): F * L * ceil(S/32) * 4 bytes on the wire — 8x less
than the int8 stream — which keeps the split-vs-unified comparison honest
once the unified kernel packs its VMEM scratch. ``radix=4`` fuses two
trellis stages per scan step (see tables.radix4_tables); both knobs are
bit-exact vs the radix-2 / unpacked seed kernel.

``layout`` re-orients the stream for the TPU's (8 sublane x 128 lane)
tiles (kernels/packing.Layout):
  * lane    — (F, L, W) int32 / (F, L, S) int8: frame-major, packed words
    (or states) trailing. The per-tile staging block lane-pads the tiny W
    dim to 128 on real Mosaic.
  * sublane — frames on the trailing lane axis: packed (L*W, F) int32
    (stage-flattened rows, like the unified kernel's scratch) or unpacked
    (L, S, F) int8. The JAX-level traceback consumes this orientation
    directly (core/traceback.*_frames), so the stream is never transposed.
``bm_dtype`` sets the branch-metric scratch dtype (see acs.py).
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.trellis import Trellis
from .acs import acs_scan
from .packing import Layout, pack_bits, packed_width

__all__ = ["forward_frames"]


def _kernel(llr_ref, sel_ref, amax_ref, bm_ref, *, trellis: Trellis, L: int,
            pack: bool, radix: int, layout: Layout, bm_dtype):
    # same forward recursion as the unified kernel (shared via acs.py);
    # only the survivor destination differs: HBM-backed output refs.
    sub = layout is Layout.SUBLANE
    W = packed_width(trellis.num_states)

    def store(t, sel, sigma):
        if sub:                                      # sel/sigma are (S, FT)
            if pack:
                sel_ref[pl.ds(t * W, W)] = pack_bits(sel, Layout.SUBLANE)
            else:
                sel_ref[t] = sel.astype(jnp.int8)
            amax_ref[:, t] = jnp.argmax(sigma, axis=0).astype(jnp.int32)
        else:                                        # sel/sigma are (FT, S)
            if pack:
                sel_ref[:, t, :] = pack_bits(sel)    # -> HBM, 1 bit/state
            else:
                sel_ref[:, t, :] = sel.astype(jnp.int8)  # 1 byte/state
            amax_ref[:, t] = jnp.argmax(sigma, axis=1).astype(jnp.int32)

    acs_scan(llr_ref, bm_ref, trellis=trellis, L=L, radix=radix, store=store,
             layout=layout, bm_dtype=bm_dtype)


@functools.partial(jax.jit, static_argnames=(
    "trellis", "frames_per_tile", "pack_survivors", "radix", "layout",
    "bm_dtype", "interpret"))
def forward_frames(frames: jax.Array, *, trellis: Trellis,
                   frames_per_tile: int = 8, pack_survivors: bool = False,
                   radix: int = 2, layout: str = "lane",
                   bm_dtype: str = "float32", interpret: bool = True):
    """(F, L, beta) llr -> (sel, amax (F, L) int32) in HBM.

    sel layout/shape: lane (F, L, S) int8 or packed (F, L, ceil(S/32))
    int32; sublane (L, S, F) int8 or packed (L*ceil(S/32), F) int32.
    """
    F, L, beta = frames.shape
    FT = frames_per_tile
    assert F % FT == 0, (F, FT)
    assert radix in (2, 4), radix
    layout = Layout(layout)
    bm_dt = jnp.dtype(bm_dtype)
    S = trellis.num_states
    half = 1 << (trellis.beta - 1)
    W = packed_width(S)
    sub = layout is Layout.SUBLANE

    if sub:
        inputs = frames.reshape(F, L * beta)
        in_spec = pl.BlockSpec((FT, L * beta), lambda i: (i, 0))
        if pack_survivors:
            sel_spec = pl.BlockSpec((L * W, FT), lambda i: (0, i))
            sel_shape = jax.ShapeDtypeStruct((L * W, F), jnp.int32)
        else:
            sel_spec = pl.BlockSpec((L, S, FT), lambda i: (0, 0, i))
            sel_shape = jax.ShapeDtypeStruct((L, S, F), jnp.int8)
        bm_scratch = pltpu.VMEM((L * half, FT), bm_dt)
    else:
        inputs = frames
        in_spec = pl.BlockSpec((FT, L, beta), lambda i: (i, 0, 0))
        sel_w = W if pack_survivors else S
        sel_dt = jnp.int32 if pack_survivors else jnp.int8
        sel_spec = pl.BlockSpec((FT, L, sel_w), lambda i: (i, 0, 0))
        sel_shape = jax.ShapeDtypeStruct((F, L, sel_w), sel_dt)
        bm_scratch = pltpu.VMEM((L, FT, half), bm_dt)

    kern = functools.partial(_kernel, trellis=trellis, L=L,
                             pack=pack_survivors, radix=radix, layout=layout,
                             bm_dtype=bm_dt)
    return pl.pallas_call(
        kern,
        grid=(F // FT,),
        in_specs=[in_spec],
        out_specs=[sel_spec, pl.BlockSpec((FT, L), lambda i: (i, 0))],
        out_shape=[sel_shape, jax.ShapeDtypeStruct((F, L), jnp.int32)],
        scratch_shapes=[bm_scratch],
        interpret=interpret,
    )(inputs)
