"""In-kernel trellis table construction.

Pallas kernels may not capture array constants, so the (small) trellis
tables are rebuilt INSIDE the kernel from iota + static python ints
(k, polys). XLA constant-folds all of this at compile time — the kernel
body still sees compile-time-constant vectors, exactly like baking numpy
tables would, but without captured-constant plumbing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.trellis import Trellis

__all__ = ["kernel_tables"]


def _parity(x: jax.Array, k: int) -> jax.Array:
    """Popcount-parity of k-bit ints (static unroll — k <= 16)."""
    out = jnp.zeros_like(x)
    for b in range(k):
        out = out ^ ((x >> b) & 1)
    return out


def kernel_tables(trellis: Trellis):
    """Build {prev (S,2), bm_idx_p, bm_sgn_p [(S,) x2], signs_half} via iota."""
    k, beta, polys = trellis.k, trellis.beta, trellis.polys
    S = 1 << (k - 1)
    half = 1 << (beta - 1)
    mask = (1 << beta) - 1
    j = jax.lax.iota(jnp.int32, S)
    binput = j >> (k - 2)                           # input bit INTO state j

    prev, idx_p, sgn_p = [], [], []
    for p in (0, 1):
        prev_p = ((j << 1) & (S - 1)) | p           # butterfly predecessor
        w = (binput << (k - 1)) | prev_p            # k-bit encoder word
        oword = jnp.zeros_like(j)
        for bi, g in enumerate(polys):
            oword = oword | (_parity(w & g, k) << (beta - 1 - bi))
        # symmetry compression (eqs. 8-9): index into 2^(beta-1) table + sign
        idx = jnp.where(oword < half, oword, mask ^ oword)
        sgn = jnp.where(oword < half, 1.0, -1.0).astype(jnp.float32)
        prev.append(prev_p)
        idx_p.append(idx)
        sgn_p.append(sgn)

    o = jax.lax.iota(jnp.int32, half)[:, None]      # (half, 1)
    bi = jax.lax.iota(jnp.int32, beta)[None, :]     # (1, beta)
    bits = (o >> (beta - 1 - bi)) & 1
    signs_half = (1.0 - 2.0 * bits).astype(jnp.float32)   # (half, beta)
    return prev, idx_p, sgn_p, signs_half
