"""In-kernel trellis table construction.

Pallas kernels may not capture array constants, so the (small) trellis
tables are rebuilt INSIDE the kernel from iota + static python ints
(k, polys). XLA constant-folds all of this at compile time — the kernel
body still sees compile-time-constant vectors, exactly like baking numpy
tables would, but without captured-constant plumbing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.trellis import Trellis

__all__ = ["kernel_tables", "radix4_tables"]


def _parity(x: jax.Array, k: int) -> jax.Array:
    """Popcount-parity of k-bit ints (static unroll — k <= 16)."""
    out = jnp.zeros_like(x)
    for b in range(k):
        out = out ^ ((x >> b) & 1)
    return out


def kernel_tables(trellis: Trellis):
    """Build {prev (S,2), bm_idx_p, bm_sgn_p [(S,) x2], signs_half} via iota."""
    k, beta, polys = trellis.k, trellis.beta, trellis.polys
    S = 1 << (k - 1)
    half = 1 << (beta - 1)
    mask = (1 << beta) - 1
    j = jax.lax.iota(jnp.int32, S)
    binput = j >> (k - 2)                           # input bit INTO state j

    prev, idx_p, sgn_p = [], [], []
    for p in (0, 1):
        prev_p = ((j << 1) & (S - 1)) | p           # butterfly predecessor
        w = (binput << (k - 1)) | prev_p            # k-bit encoder word
        oword = jnp.zeros_like(j)
        for bi, g in enumerate(polys):
            oword = oword | (_parity(w & g, k) << (beta - 1 - bi))
        # symmetry compression (eqs. 8-9): index into 2^(beta-1) table + sign
        idx = jnp.where(oword < half, oword, mask ^ oword)
        sgn = jnp.where(oword < half, 1.0, -1.0).astype(jnp.float32)
        prev.append(prev_p)
        idx_p.append(idx)
        sgn_p.append(sgn)

    o = jax.lax.iota(jnp.int32, half)[:, None]      # (half, 1)
    bi = jax.lax.iota(jnp.int32, beta)[None, :]     # (1, beta)
    bits = (o >> (beta - 1 - bi)) & 1
    signs_half = (1.0 - 2.0 * bits).astype(jnp.float32)   # (half, beta)
    return prev, idx_p, sgn_p, signs_half


def radix4_tables(trellis: Trellis):
    """Tables for the fused two-stage (radix-4) ACS pair step.

    The convolutional trellis is time-invariant, so both half-steps of a
    radix-4 pair share the butterfly predecessor permutation ``perm``.
    What the pair step DOES precompute is the fused branch-metric lookup:
    the kernel stores the two stages' compressed BM rows side by side as
    one ``(FT, 2 * half)`` vector, and ``idx2[st][p] = idx_p[p] + st*half``
    indexes straight into it — four BM gathers per pair against one fused
    table instead of two gathers against each of two rows.

    Exactness: ``take(bm2, idx2[st][p]) == take(bm_stage_st, idx_p[p])``
    element-for-element, and the pair step runs the two half-steps in the
    exact radix-2 arithmetic order (including the per-stage max-normalize),
    so radix-4 is bit-identical to radix-2 by construction — the win is a
    2x shorter scan (half the loop-control / scalar overhead per stage),
    not different arithmetic.
    """
    half = 1 << (trellis.beta - 1)
    prev, idx_p, sgn_p, signs_half = kernel_tables(trellis)
    idx2 = [[idx_p[p] + st * half for p in (0, 1)] for st in (0, 1)]
    sgn2 = [[sgn_p[p] for p in (0, 1)] for st in (0, 1)]
    return prev, idx2, sgn2, signs_half
