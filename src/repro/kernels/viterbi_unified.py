"""Unified Viterbi kernel (paper §IV-A, Alg. 3) as a Pallas TPU kernel.

The paper's central idea: fuse the forward procedure (branch metrics + ACS +
survivor paths) and the backward procedure (parallel traceback + decode) into
ONE kernel so the survivor-path matrix lives in on-chip memory (GPU shared
memory -> TPU **VMEM scratch**) and never touches HBM. The only HBM traffic
is the LLR block in and the decoded bits out — Table I row (c): global memory
for intermediate data = none.

TPU mapping (DESIGN.md §2):
  * grid = frame tiles; each grid step decodes ``FT`` frames entirely in VMEM
    (FT plays the role of "multiple frames per block" from §IV-F: it fills
    the 8 sublanes, and packs the S=64 states onto the lane dimension).
  * the ACS butterfly is arithmetic, not gathers: prev(j,p) = ((j<<1)&(S-1))|p,
    so the traceback pointer chase is pure vector integer ops; the only
    gathers are static-index permutations of the path-metric vector.
  * branch metrics are precomputed coalesced (paper Fig. 7) in the
    symmetry-compressed 2^(beta-1) form (eq. 9) into VMEM scratch.
  * the parallel traceback advances all ``nsub`` subframe cursors of all
    ``FT`` frames in lock-step: the backward pass costs f0+v2s vector steps.

Two perf knobs added on top of the seed kernel (both bit-exact vs the
pure-JAX oracle — see kernels/packing.py and kernels/tables.py):
  * ``pack_survivors``: the survivor array stores 1 selector *bit* per
    (stage, state); packing 32 states per int32 word shrinks the dominant
    VMEM array 32x and is what makes frames_per_tile >= 32 fit.
  * ``radix=4``: two trellis stages fused per scan step (and per traceback
    step) with the fused branch-metric table of ``radix4_tables`` — half
    the trip count on both hot loops, identical arithmetic per stage.

VMEM budget per grid step (K=7, L=v1+f+v2≈340, f0+v2s≈77, W=ceil(S/32)=2):

                          unpacked, FT=8          packed, FT=32
  llr block   FT*L*beta*4          ≈ 21 KiB              ≈  85 KiB
  bm (eq. 9)  L*FT*2^(b-1)*4       ≈ 21 KiB              ≈  85 KiB
  sel         L*FT*S*4             ≈ 680 KiB     L*FT*W*4 ≈ 85 KiB
  amax        L*FT*4               ≈ 10 KiB              ≈  43 KiB
  tb bits     (f0+v2s)*nsub*FT*4   ≈ 20 KiB              ≈  77 KiB
  total                            ≈ 0.75 MiB            ≈ 0.37 MiB

i.e. packing turns ``sel`` from ~90% of the footprint into a co-equal
term, so 4x the frames per tile still costs half the seed's VMEM — that
headroom is what kernels/autotune.py spends. (On real Mosaic the packed
(…, W=2) trailing dim is lane-padded to 128, so the full 32x only
materializes for S >= 4096 states or a sublane-major relayout; the
interpret-mode model and the scratch *spec* already account 32x, which is
the honest budget for the GPU target the paper describes.)
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.trellis import Trellis
from .acs import acs_scan
from .packing import extract_bit, pack_bits, packed_width

__all__ = ["unified_decode_frames"]


def _kernel(llr_ref, out_ref, sel_ref, amax_ref, bm_ref, tb_ref, *,
            trellis: Trellis, v1: int, f: int, v2: int, f0: int, v2s: int,
            start: str, pack: bool, radix: int):
    S = trellis.num_states
    kshift = trellis.k - 2
    L = v1 + f + v2
    FT = llr_ref.shape[0]
    nsub = f // f0

    # ---- phases 1+2: branch metrics + ACS, survivors stay in VMEM --------
    # (Fig. 7 / Alg. 3; recursion shared with viterbi_fwd via acs.py)
    def store(t, sel, sigma):
        sel_ref[t] = pack_bits(sel) if pack else sel.astype(jnp.int32)
        amax_ref[t] = jnp.argmax(sigma, axis=1).astype(jnp.int32)

    acs_scan(llr_ref, bm_ref, trellis=trellis, L=L, radix=radix, store=store)

    # ---- phase 3: parallel traceback (paper §IV-D, Fig. 5) ---------------
    sel_all = sel_ref[...]                           # (L, FT, W|S) VMEM read
    amax_all = amax_ref[...]                         # (L, FT)
    q = jnp.arange(nsub, dtype=jnp.int32)
    e = v1 + (q + 1) * f0 - 1 + v2s                  # chase starts, (nsub,)
    if start == "boundary":
        states = jnp.take(amax_all, e, axis=0)       # (nsub, FT)
    else:                                            # 'fixed' (Fig. 11)
        states = jnp.zeros((nsub, FT), jnp.int32)
    lane = jax.lax.broadcasted_iota(jnp.int32, (nsub, FT, S), 2)

    def sel_at(t, states):                           # selector bit (nsub,FT)
        rows = jnp.take(sel_all, t, axis=0)          # (nsub, FT, W|S)
        if pack:
            return extract_bit(rows, states)
        onehot = (states[..., None] == lane).astype(jnp.int32)
        return jnp.sum(rows * onehot, axis=2)

    def tb_step(r, states):                          # states: (nsub, FT)
        tb_ref[r] = (states >> kshift)               # decoded bits at e - r
        p = sel_at(e - r, states)
        return ((states << 1) & (S - 1)) | p         # butterfly arithmetic

    T = f0 + v2s
    if radix == 4:
        def tb_pair(r2, states):
            states = tb_step(2 * r2, states)
            return tb_step(2 * r2 + 1, states)
        states = jax.lax.fori_loop(0, T // 2, tb_pair, states)
        if T % 2:
            states = tb_step(T - 1, states)
    else:
        jax.lax.fori_loop(0, T, tb_step, states)

    # ---- phase 4: assemble + single coalesced HBM write ------------------
    tb = tb_ref[...]                                 # (f0+v2s, nsub, FT)
    kept = tb[v2s:][::-1]                            # (f0, nsub, FT) stage-asc
    out = jnp.transpose(kept, (2, 1, 0))             # (FT, nsub, f0)
    out_ref[...] = out.reshape(FT, f).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=(
    "trellis", "v1", "f", "v2", "f0", "v2s", "start", "frames_per_tile",
    "pack_survivors", "radix", "interpret"))
def unified_decode_frames(frames: jax.Array, *, trellis: Trellis, v1: int,
                          f: int, v2: int, f0: int, v2s: int,
                          start: str = "boundary", frames_per_tile: int = 8,
                          pack_survivors: bool = False, radix: int = 2,
                          interpret: bool = True) -> jax.Array:
    """Decode (F, L, beta) LLR frames -> (F, f) bits with the unified kernel.

    F must be a multiple of ``frames_per_tile`` (ops.py pads).
    ``pack_survivors`` bit-packs the VMEM survivor scratch 32x; ``radix=4``
    fuses two trellis stages per ACS/traceback step. Both are bit-exact.
    """
    F, L, beta = frames.shape
    assert L == v1 + f + v2, (L, v1, f, v2)
    assert f % f0 == 0 and v2s <= v2
    assert radix in (2, 4), radix
    FT = frames_per_tile
    assert F % FT == 0, (F, FT)
    S = trellis.num_states
    half = 1 << (trellis.beta - 1)
    nsub = f // f0
    sel_w = packed_width(S) if pack_survivors else S

    kern = functools.partial(_kernel, trellis=trellis, v1=v1, f=f, v2=v2,
                             f0=f0, v2s=v2s, start=start,
                             pack=pack_survivors, radix=radix)
    return pl.pallas_call(
        kern,
        grid=(F // FT,),
        in_specs=[pl.BlockSpec((FT, L, beta), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((FT, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((F, f), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((L, FT, sel_w), jnp.int32),   # survivors (maybe packed)
            pltpu.VMEM((L, FT), jnp.int32),          # per-stage argmax states
            pltpu.VMEM((L, FT, half), jnp.float32),  # compressed BMs (eq. 9)
            pltpu.VMEM((f0 + v2s, nsub, FT), jnp.int32),  # traceback bits
        ],
        interpret=interpret,
    )(frames)
