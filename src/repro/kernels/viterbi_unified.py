"""Unified Viterbi kernel (paper §IV-A, Alg. 3) as a Pallas TPU kernel.

The paper's central idea: fuse the forward procedure (branch metrics + ACS +
survivor paths) and the backward procedure (parallel traceback + decode) into
ONE kernel so the survivor-path matrix lives in on-chip memory (GPU shared
memory -> TPU **VMEM scratch**) and never touches HBM. The only HBM traffic
is the LLR block in and the decoded bits out — Table I row (c): global memory
for intermediate data = none.

TPU mapping (DESIGN.md §2):
  * grid = frame tiles; each grid step decodes ``FT`` frames entirely in VMEM
    (FT plays the role of "multiple frames per block" from §IV-F: it fills
    the 8 sublanes, and packs the S=64 states onto the lane dimension).
  * the ACS butterfly is arithmetic, not gathers: prev(j,p) = ((j<<1)&(S-1))|p,
    so the traceback pointer chase is pure vector integer ops; the only
    gathers are static-index permutations of the path-metric vector.
  * branch metrics are precomputed coalesced (paper Fig. 7) in the
    symmetry-compressed 2^(beta-1) form (eq. 9) into VMEM scratch.
  * the parallel traceback advances all ``nsub`` subframe cursors of all
    ``FT`` frames in lock-step: the backward pass costs f0+v2s vector steps.

VMEM budget per grid step (K=7, L=v1+f+v2≈340, FT=8, f0+v2s≈77):
  llr block       FT*L*beta*4      ≈  21 KiB
  bm (compressed) L*FT*2^(b-1)*4   ≈  21 KiB
  sel (survivors) L*FT*S*4         ≈ 680 KiB   <- the array the paper keeps
  amax            L*FT*4           ≈  10 KiB      out of global memory
  tb bits         (f0+v2s)*FT*nsub ≈  20 KiB
  total ≈ 0.75 MiB of ~16 MiB VMEM -> ~21 concurrent tiles' worth of
  headroom; FT and the grid give Mosaic room to double-buffer the LLR DMA.
  (sel could be bit-packed 32x as on GPU; int32 keeps the interpret oracle
  simple and still fits with large margin — see EXPERIMENTS.md §Perf.)
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.trellis import Trellis
from .tables import kernel_tables

__all__ = ["unified_decode_frames"]


def _kernel(llr_ref, out_ref, sel_ref, amax_ref, bm_ref, tb_ref, *,
            trellis: Trellis, v1: int, f: int, v2: int, f0: int, v2s: int,
            start: str):
    S = trellis.num_states
    kshift = trellis.k - 2
    half = 1 << (trellis.beta - 1)
    L = v1 + f + v2
    FT = llr_ref.shape[0]
    nsub = f // f0

    # trellis tables, constant-folded from iota (see tables.py)
    perm, idx_p, sgn_p, signs_half = kernel_tables(trellis)

    # ---- phase 1: coalesced, symmetry-compressed branch metrics (Fig. 7) --
    llr = llr_ref[...].astype(jnp.float32)           # (FT, L, beta)
    bm_ref[...] = jnp.einsum("flb,hb->lfh", llr, signs_half)   # (L, FT, half)

    # ---- phase 2: ACS over stages, survivors stay in VMEM (Alg. 3) -------
    def acs_step(t, sigma):                          # sigma: (FT, S)
        bmh = bm_ref[t]                              # (FT, half)
        cand = []
        for p in (0, 1):
            s_prev = jnp.take(sigma, perm[p], axis=1)              # (FT, S)
            bm = jnp.take(bmh, idx_p[p], axis=1) * sgn_p[p]        # (FT, S)
            cand.append(s_prev + bm)
        sel = (cand[1] >= cand[0])                   # ties -> i'' (Alg. 1)
        sigma = jnp.where(sel, cand[1], cand[0])
        sigma = sigma - jnp.max(sigma, axis=1, keepdims=True)      # normalize
        sel_ref[t] = sel.astype(jnp.int32)
        amax_ref[t] = jnp.argmax(sigma, axis=1).astype(jnp.int32)
        return sigma

    sigma0 = jnp.zeros((FT, S), jnp.float32)
    jax.lax.fori_loop(0, L, acs_step, sigma0)

    # ---- phase 3: parallel traceback (paper §IV-D, Fig. 5) ---------------
    sel_all = sel_ref[...]                           # (L, FT, S) — VMEM read
    amax_all = amax_ref[...]                         # (L, FT)
    q = jnp.arange(nsub, dtype=jnp.int32)
    e = v1 + (q + 1) * f0 - 1 + v2s                  # chase starts, (nsub,)
    if start == "boundary":
        states = jnp.take(amax_all, e, axis=0)       # (nsub, FT)
    else:                                            # 'fixed' (Fig. 11)
        states = jnp.zeros((nsub, FT), jnp.int32)
    lane = jax.lax.broadcasted_iota(jnp.int32, (nsub, FT, S), 2)

    def tb_step(r, states):                          # states: (nsub, FT)
        t = e - r
        tb_ref[r] = (states >> kshift)               # decoded bits at stage t
        rows = jnp.take(sel_all, t, axis=0)          # (nsub, FT, S)
        onehot = (states[..., None] == lane).astype(jnp.int32)
        p = jnp.sum(rows * onehot, axis=2)           # selector bit, (nsub,FT)
        return ((states << 1) & (S - 1)) | p         # butterfly arithmetic

    jax.lax.fori_loop(0, f0 + v2s, tb_step, states)

    # ---- phase 4: assemble + single coalesced HBM write ------------------
    tb = tb_ref[...]                                 # (f0+v2s, nsub, FT)
    kept = tb[v2s:][::-1]                            # (f0, nsub, FT) stage-asc
    out = jnp.transpose(kept, (2, 1, 0))             # (FT, nsub, f0)
    out_ref[...] = out.reshape(FT, f).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=(
    "trellis", "v1", "f", "v2", "f0", "v2s", "start", "frames_per_tile",
    "interpret"))
def unified_decode_frames(frames: jax.Array, *, trellis: Trellis, v1: int,
                          f: int, v2: int, f0: int, v2s: int,
                          start: str = "boundary", frames_per_tile: int = 8,
                          interpret: bool = True) -> jax.Array:
    """Decode (F, L, beta) LLR frames -> (F, f) bits with the unified kernel.

    F must be a multiple of ``frames_per_tile`` (ops.py pads).
    """
    F, L, beta = frames.shape
    assert L == v1 + f + v2, (L, v1, f, v2)
    assert f % f0 == 0 and v2s <= v2
    FT = frames_per_tile
    assert F % FT == 0, (F, FT)
    S = trellis.num_states
    half = 1 << (trellis.beta - 1)
    nsub = f // f0

    kern = functools.partial(_kernel, trellis=trellis, v1=v1, f=f, v2=v2,
                             f0=f0, v2s=v2s, start=start)
    return pl.pallas_call(
        kern,
        grid=(F // FT,),
        in_specs=[pl.BlockSpec((FT, L, beta), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((FT, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((F, f), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((L, FT, S), jnp.int32),       # survivor selectors
            pltpu.VMEM((L, FT), jnp.int32),          # per-stage argmax states
            pltpu.VMEM((L, FT, half), jnp.float32),  # compressed BMs (eq. 9)
            pltpu.VMEM((f0 + v2s, nsub, FT), jnp.int32),  # traceback bits
        ],
        interpret=interpret,
    )(frames)
