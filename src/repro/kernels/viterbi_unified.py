"""Unified Viterbi kernel (paper §IV-A, Alg. 3) as a Pallas TPU kernel.

The paper's central idea: fuse the forward procedure (branch metrics + ACS +
survivor paths) and the backward procedure (parallel traceback + decode) into
ONE kernel so the survivor-path matrix lives in on-chip memory (GPU shared
memory -> TPU **VMEM scratch**) and never touches HBM. The only HBM traffic
is the LLR block in and the decoded bits out — Table I row (c): global memory
for intermediate data = none.

TPU mapping (DESIGN.md §2):
  * grid = frame tiles; each grid step decodes ``FT`` frames entirely in VMEM
    (FT plays the role of "multiple frames per block" from §IV-F).
  * the ACS butterfly is arithmetic, not gathers: prev(j,p) = ((j<<1)&(S-1))|p,
    so the traceback pointer chase is pure vector integer ops; the only
    gathers are static-index permutations of the path-metric vector.
  * branch metrics are precomputed coalesced (paper Fig. 7) in the
    symmetry-compressed 2^(beta-1) form (eq. 9) into VMEM scratch, stored in
    ``bm_dtype`` (float32, or bfloat16 to halve that term; path metrics
    always accumulate in float32).
  * the parallel traceback advances all ``nsub`` subframe cursors of all
    ``FT`` frames in lock-step: the backward pass costs f0+v2s vector steps.

Memory layouts (kernels/packing.Layout; paper §IV-F "multiple frames per
block" meets the TPU's (8 sublane x 128 lane) tiles):
  * ``lane``    — PR-1 orientation: frames on sublanes, states on lanes;
    packed survivor words sit on the trailing lane axis. Right for small FT
    (the FT x S transpose fills lanes with states), but on real Mosaic the
    trailing W=ceil(S/32) words are lane-padded to 128, so the 32x packing
    only materializes in interpret mode.
  * ``sublane`` — Mosaic-native: frames fill the 128 lanes, the recursion
    runs transposed (S, FT), and the two big scratches are FLAT 2D arrays —
    survivors (L*W, FT), branch metrics (L*half, FT) — so the tiny W/half
    dims are absorbed into the sublane axis instead of being padded to a
    full tile. The LLR block arrives flattened (FT, L*beta) for the same
    reason. This is the layout that keeps the 32x compression on hardware.

VMEM budget per grid step, K=7 / L=340 / f0+v2s=77 / W=2 / half=2, packed
survivors, logical vs Mosaic-padded ((8,128) f32/int32 tiles) bytes:

                    lane, FT=32            sublane, FT=128
                  logical   padded        logical   padded
  llr block        85 KiB   5.38 MiB      340 KiB   384 KiB
  bm (eq. 9)       85 KiB   5.31 MiB      340 KiB   340 KiB   (bf16: 172)
  sel survivors    85 KiB   5.31 MiB      340 KiB   340 KiB
  amax             43 KiB   168 KiB       170 KiB   172 KiB
  tb bits          77 KiB   308 KiB       308 KiB   308 KiB
  out block        32 KiB    32 KiB       128 KiB   128 KiB
  total          ~0.40 MiB ~16.5 MiB     ~1.59 MiB ~1.63 MiB

i.e. the lane layout's interpret-mode budget is a fiction on hardware (its
padded footprint exceeds the whole 16 MiB VMEM at FT=32), while the
sublane layout decodes 4x the frames in ~1/10th the padded footprint —
that is what kernels/autotune.py's ``mosaic_padded_bytes`` model spends.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.trellis import Trellis
from .acs import acs_scan
from .packing import Layout, extract_bit, pack_bits, packed_width

__all__ = ["unified_decode_frames"]


def _kernel(llr_ref, out_ref, sel_ref, amax_ref, bm_ref, tb_ref, *,
            trellis: Trellis, v1: int, f: int, v2: int, f0: int, v2s: int,
            start: str, pack: bool, radix: int, layout: Layout, bm_dtype):
    S = trellis.num_states
    kshift = trellis.k - 2
    L = v1 + f + v2
    FT = llr_ref.shape[0]
    nsub = f // f0
    sub = layout is Layout.SUBLANE
    W = packed_width(S)

    # ---- phases 1+2: branch metrics + ACS, survivors stay in VMEM --------
    # (Fig. 7 / Alg. 3; recursion shared with viterbi_fwd via acs.py).
    # LANE: sel/sigma are (FT, S); SUBLANE: transposed (S, FT).
    def store(t, sel, sigma):
        if sub:
            if pack:
                sel_ref[pl.ds(t * W, W)] = pack_bits(sel, Layout.SUBLANE)
            else:
                sel_ref[t] = sel.astype(jnp.int32)
            amax_ref[t] = jnp.argmax(sigma, axis=0).astype(jnp.int32)
        else:
            sel_ref[t] = pack_bits(sel) if pack else sel.astype(jnp.int32)
            amax_ref[t] = jnp.argmax(sigma, axis=1).astype(jnp.int32)

    acs_scan(llr_ref, bm_ref, trellis=trellis, L=L, radix=radix, store=store,
             layout=layout, bm_dtype=bm_dtype)

    # ---- phase 3: parallel traceback (paper §IV-D, Fig. 5) ---------------
    sel_all = sel_ref[...]                           # whole survivor scratch
    if sub and pack:
        sel_all = sel_all.reshape(L, W, FT)          # flat rows -> stages
    amax_all = amax_ref[...]                         # (L, FT)
    q = jnp.arange(nsub, dtype=jnp.int32)
    e = v1 + (q + 1) * f0 - 1 + v2s                  # chase starts, (nsub,)
    if start == "boundary":
        states = jnp.take(amax_all, e, axis=0)       # (nsub, FT)
    else:                                            # 'fixed' (Fig. 11)
        states = jnp.zeros((nsub, FT), jnp.int32)

    def sel_at(t, states):                           # selector bit (nsub,FT)
        rows = jnp.take(sel_all, t, axis=0)
        if sub:                                      # rows (nsub, W|S, FT)
            if pack:
                return extract_bit(rows, states, Layout.SUBLANE)
            lane = jax.lax.broadcasted_iota(jnp.int32, (nsub, S, FT), 1)
            onehot = (states[:, None, :] == lane).astype(jnp.int32)
            return jnp.sum(rows * onehot, axis=1)
        if pack:                                     # rows (nsub, FT, W|S)
            return extract_bit(rows, states)
        lane = jax.lax.broadcasted_iota(jnp.int32, (nsub, FT, S), 2)
        onehot = (states[..., None] == lane).astype(jnp.int32)
        return jnp.sum(rows * onehot, axis=2)

    def tb_step(r, states):                          # states: (nsub, FT)
        tb_ref[r] = (states >> kshift)               # decoded bits at e - r
        p = sel_at(e - r, states)
        return ((states << 1) & (S - 1)) | p         # butterfly arithmetic

    T = f0 + v2s
    if radix == 4:
        def tb_pair(r2, states):
            states = tb_step(2 * r2, states)
            return tb_step(2 * r2 + 1, states)
        states = jax.lax.fori_loop(0, T // 2, tb_pair, states)
        if T % 2:
            states = tb_step(T - 1, states)
    else:
        states = jax.lax.fori_loop(0, T, tb_step, states)

    # ---- phase 4: assemble + single coalesced HBM write ------------------
    tb = tb_ref[...]                                 # (f0+v2s, nsub, FT)
    kept = tb[v2s:][::-1]                            # (f0, nsub, FT) stage-asc
    out = jnp.transpose(kept, (2, 1, 0))             # (FT, nsub, f0)
    out_ref[...] = out.reshape(FT, f).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=(
    "trellis", "v1", "f", "v2", "f0", "v2s", "start", "frames_per_tile",
    "pack_survivors", "radix", "layout", "bm_dtype", "interpret"))
def unified_decode_frames(frames: jax.Array, *, trellis: Trellis, v1: int,
                          f: int, v2: int, f0: int, v2s: int,
                          start: str = "boundary", frames_per_tile: int = 8,
                          pack_survivors: bool = False, radix: int = 2,
                          layout: str = "lane", bm_dtype: str = "float32",
                          interpret: bool = True) -> jax.Array:
    """Decode (F, L, beta) LLR frames -> (F, f) bits with the unified kernel.

    F must be a multiple of ``frames_per_tile`` (ops.py pads).
    ``pack_survivors`` bit-packs the VMEM survivor scratch 32x; ``radix=4``
    fuses two trellis stages per ACS/traceback step; ``layout`` picks the
    lane (frames-on-sublanes) or Mosaic-native sublane (frames-on-lanes)
    orientation. All are bit-exact. ``bm_dtype='bfloat16'`` stores the
    branch metrics compressed (fp32 accumulation): not bit-exact, but BER-
    neutral to within 1e-3 (tests/test_ber.py).
    """
    F, L, beta = frames.shape
    assert L == v1 + f + v2, (L, v1, f, v2)
    assert f % f0 == 0 and v2s <= v2
    assert radix in (2, 4), radix
    layout = Layout(layout)
    bm_dt = jnp.dtype(bm_dtype)
    FT = frames_per_tile
    assert F % FT == 0, (F, FT)
    S = trellis.num_states
    half = 1 << (trellis.beta - 1)
    nsub = f // f0
    W = packed_width(S)
    sub = layout is Layout.SUBLANE

    if sub:                       # flat LLR block: L*beta on the lane axis
        inputs = frames.reshape(F, L * beta)
        in_spec = pl.BlockSpec((FT, L * beta), lambda i: (i, 0))
        sel_scratch = (pltpu.VMEM((L * W, FT), jnp.int32) if pack_survivors
                       else pltpu.VMEM((L, S, FT), jnp.int32))
        bm_scratch = pltpu.VMEM((L * half, FT), bm_dt)
    else:
        inputs = frames
        in_spec = pl.BlockSpec((FT, L, beta), lambda i: (i, 0, 0))
        sel_w = W if pack_survivors else S
        sel_scratch = pltpu.VMEM((L, FT, sel_w), jnp.int32)
        bm_scratch = pltpu.VMEM((L, FT, half), bm_dt)

    kern = functools.partial(_kernel, trellis=trellis, v1=v1, f=f, v2=v2,
                             f0=f0, v2s=v2s, start=start,
                             pack=pack_survivors, radix=radix, layout=layout,
                             bm_dtype=bm_dt)
    return pl.pallas_call(
        kern,
        grid=(F // FT,),
        in_specs=[in_spec],
        out_specs=pl.BlockSpec((FT, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((F, f), jnp.int32),
        scratch_shapes=[
            sel_scratch,                             # survivors (maybe packed)
            pltpu.VMEM((L, FT), jnp.int32),          # per-stage argmax states
            bm_scratch,                              # compressed BMs (eq. 9)
            pltpu.VMEM((f0 + v2s, nsub, FT), jnp.int32),  # traceback bits
        ],
        interpret=interpret,
    )(inputs)
