"""Pallas TPU kernels for the paper's compute hot-spot (validated with
interpret=True on CPU; see EXAMPLE.md for the layout convention).

Submodules (``ops``, ``ref``, ``autotune``, ``packing``, ...) are imported
on first use rather than eagerly: ``core.traceback`` consumes the layout
vocabulary of ``kernels.packing``, and an eager ``from . import ops`` here
would re-enter ``repro.core`` mid-import — kernels.packing depends on
nothing, everything above it may depend on it. Attribute access
(``repro.kernels.ops``) and ``from repro.kernels import ops`` both work;
the module __getattr__ below resolves them on demand.
"""
import importlib

_SUBMODULES = ("acs", "autotune", "block", "ops", "packing", "ref", "tables",
               "tunedb", "viterbi_fwd", "viterbi_unified")


def __getattr__(name):
    if name in _SUBMODULES:
        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_SUBMODULES))
