"""Pallas TPU kernels for the paper's compute hot-spot (validated with
interpret=True on CPU; see EXAMPLE.md for the layout convention)."""
from . import ops, ref  # noqa: F401
