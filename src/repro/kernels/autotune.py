"""VMEM-budget-driven tile planner for the Pallas Viterbi kernels.

The seed hard-coded ``frames_per_tile=8``. That number is a *memory*
decision in disguise: each grid step of the unified kernel keeps the whole
per-tile working set (LLR block, compressed branch metrics, survivor
array, argmax trace, traceback bits, output block) resident in VMEM, so
the right tile size is "as many frames as the VMEM budget allows" — more
frames per tile amortizes the fixed per-step scan overhead and gives
Mosaic a longer-lived block to pipeline DMA against (paper §IV-F,
"multiple frames per block").

``plan_tiles`` picks the largest power-of-two tile whose unified-kernel
footprint fits a conservative budget (default 2 MiB of the ~16 MiB VMEM:
leaves room for double-buffered LLR DMA and concurrent tiles), after
validating the FrameSpec's subframe geometry. With packed survivors the
dominant array shrinks 32x, which is what moves the plan from FT=8-16 to
FT>=32 — the acceptance target of this optimization.
"""
from __future__ import annotations

import dataclasses

from ..core.framed import FrameSpec
from ..core.trellis import Trellis
from .packing import packed_width

__all__ = ["TilePlan", "unified_vmem_bytes", "plan_tiles",
           "DEFAULT_VMEM_BUDGET", "CANDIDATE_TILES"]
# (subframe-geometry validation lives on FrameSpec.validate itself)

DEFAULT_VMEM_BUDGET = 2 * 1024 * 1024          # bytes, per grid step
CANDIDATE_TILES = (8, 16, 32, 64, 128, 256)    # powers of two >= 1 sublane


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """Chosen tile size + the footprint that justified it."""
    frames_per_tile: int
    vmem_bytes: int
    breakdown: tuple          # ((name, bytes), ...) for reports/debugging
    budget: int

    def utilization(self) -> float:
        return self.vmem_bytes / self.budget


def _geometry(spec: FrameSpec):
    """(f0, v2s) as the kernel sees them (serial tb = one full subframe)."""
    if spec.parallel_tb:
        return spec.f0, spec.v2s
    return spec.f, spec.v2


def unified_vmem_bytes(trellis: Trellis, spec: FrameSpec,
                       frames_per_tile: int, *, pack_survivors: bool = False,
                       radix: int = 2):
    """(total_bytes, breakdown) of one unified-kernel grid step.

    Mirrors the scratch_shapes + block specs in viterbi_unified.py exactly;
    ``radix`` does not change the footprint (the fused BM row is a
    transient concatenation), it is accepted so call sites can pass the
    full kernel config through one interface.
    """
    del radix
    S = trellis.num_states
    beta = trellis.beta
    half = 1 << (beta - 1)
    L = spec.frame_len
    FT = frames_per_tile
    f0, v2s = _geometry(spec)
    nsub = spec.f // f0
    sel_w = packed_width(S) if pack_survivors else S

    breakdown = (
        ("llr_block", FT * L * beta * 4),
        ("bm_compressed", L * FT * half * 4),
        ("sel_survivors", L * FT * sel_w * 4),
        ("amax", L * FT * 4),
        ("tb_bits", (f0 + v2s) * nsub * FT * 4),
        ("out_block", FT * spec.f * 4),
    )
    return sum(b for _, b in breakdown), breakdown


def plan_tiles(trellis: Trellis, spec: FrameSpec, *,
               pack_survivors: bool = False, radix: int = 2,
               vmem_budget: int = DEFAULT_VMEM_BUDGET,
               max_frames: int | None = None) -> TilePlan:
    """Pick frames_per_tile for the unified kernel from the VMEM budget.

    Returns the largest candidate tile that fits ``vmem_budget``; the
    smallest candidate is returned even when over budget (the kernel still
    runs — headroom just shrinks). ``max_frames`` caps the tile near the
    actual frame count so short streams don't decode mostly padding.
    """
    spec.validate()
    candidates = list(CANDIDATE_TILES)
    if max_frames is not None:
        # smallest candidate covering the stream in one tile is enough
        cap = next((c for c in candidates if c >= max_frames),
                   candidates[-1])
        candidates = [c for c in candidates if c <= cap]

    best = None
    for ft in candidates:
        total, breakdown = unified_vmem_bytes(
            trellis, spec, ft, pack_survivors=pack_survivors, radix=radix)
        plan = TilePlan(ft, total, breakdown, vmem_budget)
        if total <= vmem_budget or best is None:
            best = plan
        if total > vmem_budget:
            break
    return best
