"""VMEM-budget-driven tile planner for the Pallas Viterbi kernels.

The seed hard-coded ``frames_per_tile=8``. That number is a *memory*
decision in disguise: each grid step of the unified kernel keeps the whole
per-tile working set (LLR block, compressed branch metrics, survivor
array, argmax trace, traceback bits, output block) resident in VMEM, so
the right tile size is "as many frames as the VMEM budget allows" — more
frames per tile amortizes the fixed per-step scan overhead and gives
Mosaic a longer-lived block to pipeline DMA against (paper §IV-F,
"multiple frames per block").

Two accounting models:

* **logical** bytes — element counts x itemsize. This is what the scratch
  *specs* declare, what interpret mode allocates, and the honest budget
  for the GPU shared-memory target the paper describes.
* **mosaic** bytes (``mosaic_padded_bytes``) — what a real TPU allocates:
  the trailing dim of every >=2D array is padded to 128 lanes and the
  second-to-last to 32/itemsize sublanes. Under this model the lane
  layout's packed ``(.., W=2)`` survivors balloon 64x, which is exactly
  why the sublane layout (frames on lanes, flat stage-major scratches)
  exists — see viterbi_unified.py's budget table.

``plan_tiles`` picks the largest power-of-two tile whose footprint fits a
conservative budget (default 2 MiB of the ~16 MiB VMEM: leaves room for
double-buffered LLR DMA and concurrent tiles), for either kernel
(``unified=False`` uses the split kernel's smaller per-step footprint),
either layout, and either branch-metric dtype. ``plan_decode`` goes one
step further and returns the FULL plan the decode front-end executes —
kernel, layout (``'auto'`` compares both under mosaic accounting), tile,
and the per-chunk frame count the streaming front-end (core/stream.py)
feeds each device.
"""
from __future__ import annotations

import dataclasses
import math

from ..core.framed import FrameSpec
from ..core.trellis import Trellis
from ..obs.tracer import get_tracer
from .block import resolve_block
from .packing import Layout, packed_width
from .tunedb import TUNE_DB, TuneDB, platform_id

__all__ = ["TilePlan", "DecodePlan", "mosaic_padded_bytes",
           "unified_vmem_bytes", "split_vmem_bytes", "plan_tiles",
           "plan_decode", "measure_plan", "DEFAULT_VMEM_BUDGET",
           "CANDIDATE_TILES", "MAX_FRAMES_PER_TILE"]
# (subframe-geometry validation lives on FrameSpec.validate itself)

DEFAULT_VMEM_BUDGET = 2 * 1024 * 1024          # bytes, per grid step
#: Hard ceiling on tile candidates. The old 256 cap (ROADMAP open item) is
#: lifted: candidates are generated from the budget up to the frame count —
#: the footprint models are linear in FT, so the loop in plan_tiles stops
#: at the budget long before this backstop on any realistic budget.
MAX_FRAMES_PER_TILE = 4096
CANDIDATE_TILES = tuple(8 << i for i in
                        range((MAX_FRAMES_PER_TILE // 8).bit_length()))

_BM_ITEMSIZE = {"float32": 4, "bfloat16": 2}


def _rup(n: int, m: int) -> int:
    return -(-n // m) * m


def mosaic_padded_bytes(shape: tuple, itemsize: int) -> int:
    """Bytes a real Mosaic allocation pays for ``shape``: last dim padded
    to 128 lanes, second-to-last to the dtype's sublane count (8 for 4-byte,
    16 for 2-byte, 32 for 1-byte), leading dims multiply. 1D arrays pay a
    whole minimum tile."""
    if len(shape) == 1:
        shape = (1,) + tuple(shape)
    lead = math.prod(shape[:-2]) if len(shape) > 2 else 1
    return (lead * _rup(shape[-2], 32 // itemsize) * _rup(shape[-1], 128)
            * itemsize)


def _bm_itemsize(bm_dtype) -> int:
    try:
        return _BM_ITEMSIZE[str(bm_dtype)]
    except KeyError:
        raise ValueError(f"bm_dtype must be one of {sorted(_BM_ITEMSIZE)}, "
                         f"got {bm_dtype!r}")


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """Chosen tile size + the footprint that justified it."""
    frames_per_tile: int
    vmem_bytes: int
    breakdown: tuple          # ((name, bytes), ...) for reports/debugging
    budget: int
    kernel: str = "unified"   # 'unified' | 'split'
    layout: Layout = Layout.LANE
    bm_dtype: str = "float32"
    mosaic: bool = False      # padded (hardware) or logical accounting

    def utilization(self) -> float:
        return self.vmem_bytes / self.budget

    def cache_key(self) -> tuple:
        """The knobs that select a distinct compiled kernel — the tile's
        contribution to the compiled-plan cache key (serve.plan_cache).
        Footprint/budget bookkeeping is deliberately excluded: two plans
        that picked the same knobs compile to the same kernel."""
        return (self.kernel, int(self.frames_per_tile),
                Layout(self.layout).value, str(self.bm_dtype))


def _geometry(spec: FrameSpec):
    """(f0, v2s) as the kernel sees them (serial tb = one full subframe)."""
    if spec.parallel_tb:
        return spec.f0, spec.v2s
    return spec.f, spec.v2


def _shapes_unified(trellis: Trellis, spec: FrameSpec, FT: int,
                    pack: bool, layout: Layout, bm_isz: int):
    """((name, shape, itemsize), ...) mirroring viterbi_unified.py exactly."""
    S = trellis.num_states
    beta = trellis.beta
    half = 1 << (beta - 1)
    L = spec.frame_len
    W = packed_width(S)
    f0, v2s = _geometry(spec)
    nsub = spec.f // f0
    if layout is Layout.SUBLANE:
        sel = (L * W, FT) if pack else (L, S, FT)
        return (
            ("llr_block", (FT, L * beta), 4),
            ("bm_compressed", (L * half, FT), bm_isz),
            ("sel_survivors", sel, 4),
            ("amax", (L, FT), 4),
            ("tb_bits", (f0 + v2s, nsub, FT), 4),
            ("out_block", (FT, spec.f), 4),
        )
    sel_w = W if pack else S
    return (
        ("llr_block", (FT, L, beta), 4),
        ("bm_compressed", (L, FT, half), bm_isz),
        ("sel_survivors", (L, FT, sel_w), 4),
        ("amax", (L, FT), 4),
        ("tb_bits", (f0 + v2s, nsub, FT), 4),
        ("out_block", (FT, spec.f), 4),
    )


def _shapes_split(trellis: Trellis, spec: FrameSpec, FT: int,
                  pack: bool, layout: Layout, bm_isz: int):
    """((name, shape, itemsize), ...) mirroring viterbi_fwd.py: the per-step
    working set is the LLR block, the bm scratch, and the staged sel/amax
    output blocks — no survivor scratch and no traceback arrays (those live
    in HBM / run as a separate JAX step)."""
    S = trellis.num_states
    beta = trellis.beta
    half = 1 << (beta - 1)
    L = spec.frame_len
    W = packed_width(S)
    if layout is Layout.SUBLANE:
        sel = ((L * W, FT), 4) if pack else ((L, S, FT), 1)
        return (
            ("llr_block", (FT, L * beta), 4),
            ("bm_compressed", (L * half, FT), bm_isz),
            ("sel_stream", *sel),
            ("amax_stream", (FT, L), 4),
        )
    sel = ((FT, L, W), 4) if pack else ((FT, L, S), 1)
    return (
        ("llr_block", (FT, L, beta), 4),
        ("bm_compressed", (L, FT, half), bm_isz),
        ("sel_stream", *sel),
        ("amax_stream", (FT, L), 4),
    )


def _footprint(shapes, mosaic: bool):
    if mosaic:
        breakdown = tuple((n, mosaic_padded_bytes(s, i)) for n, s, i in shapes)
    else:
        breakdown = tuple((n, math.prod(s) * i) for n, s, i in shapes)
    return sum(b for _, b in breakdown), breakdown


def _resolve(layout, mosaic):
    layout = Layout(layout)
    if mosaic is None:
        # the sublane layout exists to survive hardware padding, so it is
        # judged by it; the lane layout keeps the interpret-mode (logical)
        # model that PR-1 plans were made with
        mosaic = layout is Layout.SUBLANE
    return layout, mosaic


def unified_vmem_bytes(trellis: Trellis, spec: FrameSpec,
                       frames_per_tile: int, *, pack_survivors: bool = False,
                       radix: int = 2, layout=Layout.LANE,
                       bm_dtype: str = "float32", mosaic: bool | None = None):
    """(total_bytes, breakdown) of one unified-kernel grid step.

    Mirrors the scratch_shapes + block specs in viterbi_unified.py exactly;
    ``radix`` does not change the footprint (the fused BM row is a
    transient concatenation), it is accepted so call sites can pass the
    full kernel config through one interface. ``mosaic=None`` defaults to
    padded accounting for the sublane layout, logical for lane.
    """
    del radix
    layout, mosaic = _resolve(layout, mosaic)
    shapes = _shapes_unified(trellis, spec, frames_per_tile, pack_survivors,
                             layout, _bm_itemsize(bm_dtype))
    return _footprint(shapes, mosaic)


def split_vmem_bytes(trellis: Trellis, spec: FrameSpec,
                     frames_per_tile: int, *, pack_survivors: bool = False,
                     radix: int = 2, layout=Layout.LANE,
                     bm_dtype: str = "float32", mosaic: bool | None = None):
    """(total_bytes, breakdown) of one split (forward) kernel grid step —
    the smaller footprint plan_tiles(unified=False) budgets against."""
    del radix
    layout, mosaic = _resolve(layout, mosaic)
    shapes = _shapes_split(trellis, spec, frames_per_tile, pack_survivors,
                           layout, _bm_itemsize(bm_dtype))
    return _footprint(shapes, mosaic)


def plan_tiles(trellis: Trellis, spec: FrameSpec, *,
               pack_survivors: bool = False, radix: int = 2,
               vmem_budget: int = DEFAULT_VMEM_BUDGET,
               max_frames: int | None = None, unified: bool = True,
               layout=Layout.LANE, bm_dtype: str = "float32",
               mosaic: bool | None = None) -> TilePlan:
    """Pick frames_per_tile for one kernel configuration from a VMEM budget.

    Returns the largest candidate tile that fits ``vmem_budget``; the
    smallest candidate is returned even when over budget (the kernel still
    runs — headroom just shrinks). Candidates are powers of two generated
    from the budget up to the frame count: growth stops at the first
    over-budget tile, ``max_frames`` caps the tile near the actual frame
    count so short streams don't decode mostly padding, and only the
    MAX_FRAMES_PER_TILE backstop bounds an effectively unlimited budget
    (the 256 cap of PR 1 is gone — sublane plans beyond 256 frames are
    real configurations at larger budgets).
    ``unified=False`` budgets the split (forward-only) kernel's footprint.
    """
    spec.validate()
    layout, mosaic = _resolve(layout, mosaic)
    model = unified_vmem_bytes if unified else split_vmem_bytes
    candidates = list(CANDIDATE_TILES)
    if max_frames is not None:
        # smallest candidate covering the stream in one tile is enough
        cap = next((c for c in candidates if c >= max_frames),
                   candidates[-1])
        candidates = [c for c in candidates if c <= cap]

    best = None
    for ft in candidates:
        total, breakdown = model(
            trellis, spec, ft, pack_survivors=pack_survivors, radix=radix,
            layout=layout, bm_dtype=bm_dtype, mosaic=mosaic)
        plan = TilePlan(ft, total, breakdown, vmem_budget,
                        "unified" if unified else "split", layout,
                        str(bm_dtype), mosaic)
        if total <= vmem_budget or best is None:
            best = plan
        if total > vmem_budget:
            break
    return best


@dataclasses.dataclass(frozen=True)
class DecodePlan:
    """The full configuration the decode front-end executes: kernel knobs
    (tile) plus the streaming geometry (chunk sizing across devices).
    ``block_frames``/``overlap`` are the intra-frame block-parallel knobs
    (kernels/block.py), always stored RESOLVED (1/0 = blocking off); when
    on, ``tile`` is budgeted against the derived per-block spec — the
    short frames the kernel actually sees — and ``frames_per_tile``
    counts those blocks, not outer frames."""
    tile: TilePlan
    pack_survivors: bool
    radix: int
    chunk_frames: int         # frames the stream front-end batches per chunk
    num_devices: int          # chunk_frames is a multiple of tiles x devices
    block_frames: int = 1     # intra-frame blocks per frame (1 = off)
    overlap: int = 0          # per-block training/truncation stages

    @property
    def unified(self) -> bool:
        return self.tile.kernel == "unified"

    @property
    def frames_per_tile(self) -> int:
        return self.tile.frames_per_tile

    def kernel_kwargs(self) -> dict:
        """kwargs for ops.viterbi_decode_frames, ready to splat."""
        return dict(unified=self.unified,
                    frames_per_tile=self.tile.frames_per_tile,
                    pack_survivors=self.pack_survivors, radix=self.radix,
                    layout=self.tile.layout.value,
                    bm_dtype=self.tile.bm_dtype,
                    block_frames=self.block_frames, overlap=self.overlap)

    def cache_key(self) -> tuple:
        """Stable, hashable identity of the full plan: everything that
        changes the compiled decode (kernel knobs — including the block
        decomposition, which changes the decoded BITS) or the launch
        geometry (chunk sizing across devices). Together with (trellis,
        spec, nframes) this keys the compiled-plan cache and the serve
        layer's session buckets."""
        return (*self.tile.cache_key(), bool(self.pack_survivors),
                int(self.radix), int(self.chunk_frames),
                int(self.num_devices), int(self.block_frames),
                int(self.overlap))

    def fingerprint(self) -> str:
        """Short hex digest of cache_key() — a human-greppable bucket id
        for metrics rows and benchmark records."""
        import hashlib
        return hashlib.sha1(repr(self.cache_key()).encode()).hexdigest()[:10]


def measure_plan(trellis: Trellis, spec: FrameSpec, plan: DecodePlan, *,
                 reps: int = 2, frames: int | None = None,
                 interpret: bool | None = None) -> dict:
    """Time one DecodePlan with real launches of the kernel it selects.

    One warm-up launch pays the compile, then ``reps`` timed launches keep
    the minimum (the least-noisy estimator on a shared machine — same
    discipline as benchmarks/throughput.py). The launch geometry is the
    plan's own: ``frames`` defaults to ``plan.chunk_frames``, the chunk the
    streaming front-end would actually feed this plan, so the record prices
    padding and pipelining exactly as production launches would.

    ``interpret`` defaults to True only on the CPU backend (Pallas kernels
    need the interpreter there); on a real accelerator the launch is
    compiled — that is the whole point of measuring.

    Returns the tune-DB record: ``{ms, mbps, frames, reps, interpret,
    fingerprint}``. Pure timing — callers decide whether to persist it
    (``plan_decode(measure=True)`` does, via TuneDB).
    """
    import time as _time

    import numpy as np
    import jax.numpy as jnp

    from . import ops            # lazy: ops imports this module at top level

    if interpret is None:
        interpret = platform_id()["backend"] == "cpu"
    F = int(frames if frames is not None else plan.chunk_frames)
    rng = np.random.default_rng(0)
    llr = jnp.asarray(rng.standard_normal(
        (F, spec.frame_len, trellis.beta)).astype(np.float32))
    kw = plan.kernel_kwargs()

    def launch():
        return ops.viterbi_decode_frames(llr, trellis, spec,
                                         interpret=bool(interpret), **kw)

    launch().block_until_ready()             # compile + warm-up
    best = math.inf
    for _ in range(max(1, int(reps))):
        t0 = _time.perf_counter()
        launch().block_until_ready()
        best = min(best, _time.perf_counter() - t0)
    bits = F * spec.f
    return {"ms": best * 1e3, "mbps": bits / best / 1e6, "frames": F,
            "reps": int(reps), "interpret": bool(interpret),
            "fingerprint": plan.fingerprint()}


def _tile_at(trellis: Trellis, plan_spec: FrameSpec, ft: int, *,
             unified: bool, pack_survivors: bool, radix: int, layout: Layout,
             bm_dtype: str, mosaic: bool, vmem_budget: int) -> TilePlan:
    """A TilePlan at an arbitrary tile size under the same accounting as
    the analytic winner — candidate variants for the measuring pass."""
    model = unified_vmem_bytes if unified else split_vmem_bytes
    total, breakdown = model(trellis, plan_spec, ft,
                             pack_survivors=pack_survivors, radix=radix,
                             layout=layout, bm_dtype=bm_dtype, mosaic=mosaic)
    return TilePlan(int(ft), total, breakdown, vmem_budget,
                    "unified" if unified else "split", Layout(layout),
                    str(bm_dtype), bool(mosaic))


def _measure_candidates(trellis: Trellis, plan_spec: FrameSpec,
                        analytic: DecodePlan, *, layout, unified: bool,
                        pack_survivors: bool, radix: int, bm_dtype: str,
                        vmem_budget: int, eff_max, num_devices: int,
                        bf: int, ov: int, chunk_frames, top_k: int):
    """Top-k candidate plans for the timing pass: the analytic winner, the
    other layout's winner (layout='auto' only — the measurement exists to
    second-guess exactly this padding-model comparison), and the half/double
    tile variants of the winner (the footprint model is linear, but launch
    overhead vs pipelining is not). Deduped by cache_key; analytic order
    kept so ties resolve to the model's choice."""
    tiles = [analytic.tile]
    if layout == "auto":
        for lay in (Layout.LANE, Layout.SUBLANE):
            if lay is not analytic.tile.layout:
                tiles.append(plan_tiles(
                    trellis, plan_spec, pack_survivors=pack_survivors,
                    radix=radix, vmem_budget=vmem_budget, max_frames=eff_max,
                    unified=unified, layout=lay, bm_dtype=bm_dtype,
                    mosaic=True))
    ft0 = analytic.tile.frames_per_tile
    for ft in (ft0 // 2, ft0 * 2):
        if CANDIDATE_TILES[0] <= ft <= MAX_FRAMES_PER_TILE:
            tiles.append(_tile_at(
                trellis, plan_spec, ft, unified=unified,
                pack_survivors=pack_survivors, radix=radix,
                layout=analytic.tile.layout, bm_dtype=bm_dtype,
                mosaic=analytic.tile.mosaic, vmem_budget=vmem_budget))
    out, seen = [], set()
    for t in tiles:
        cf = (int(chunk_frames) if chunk_frames is not None
              else 2 * max(1, t.frames_per_tile // bf) * num_devices)
        p = DecodePlan(t, pack_survivors, radix, cf, num_devices, bf, ov)
        k = p.cache_key()
        if k not in seen:
            seen.add(k)
            out.append(p)
    return out[:max(1, int(top_k))]


def plan_decode(trellis: Trellis, spec: FrameSpec, *, unified: bool = True,
                pack_survivors: bool = True, radix: int = 4,
                bm_dtype: str = "float32", layout="auto",
                vmem_budget: int = DEFAULT_VMEM_BUDGET, num_devices: int = 1,
                chunk_frames: int | None = None,
                max_frames: int | None = None,
                frames_per_tile: int | None = None,
                block_frames: int | str = 1,
                overlap: int | None = None,
                measure: bool = False, tunedb: TuneDB | None = None,
                measure_top_k: int = 3, measure_reps: int = 2,
                measure_frames: int | None = None) -> DecodePlan:
    """Plan the whole decode: kernel, layout, tile, and chunk geometry.

    ``layout='auto'`` evaluates both layouts under mosaic (hardware-padded)
    accounting and keeps the one that fits more frames per tile at the
    given per-device ``vmem_budget`` (ties: fewer padded bytes) — the
    FT x S lane transpose wins only when tiles are small enough that
    frames cannot fill the 128 lanes. ``chunk_frames`` defaults to two
    tiles per device so the streaming front-end can double-buffer.
    ``frames_per_tile`` pins the tile instead of autotuning it (the serve
    layer passes a session's explicit knob through here so the plan — and
    its padding accounting — matches the kernel that actually launches).

    ``block_frames``/``overlap`` are the intra-frame block-parallel knobs
    (kernels/block.py): an int, or ``"auto"`` to engage blocking past
    BLOCK_LEN_THRESHOLD kept stages. When blocking is on, the tile is
    budgeted against the DERIVED per-block spec — the planner trades
    frames-per-tile against blocks-per-frame under the same VMEM model,
    so a long frame that only fits a handful of sequential scans per tile
    becomes many short blocks that fill the tile instead. Tile counts and
    ``frames_per_tile`` are then in block units; ``chunk_frames`` stays in
    OUTER frames (what core/stream.py slices), defaulting to two tiles'
    worth of whole frames per device.

    ``measure=True`` adds the on-device timing pass (ROADMAP item 3): the
    top-k analytic candidates (``_measure_candidates``) are timed with real
    launches (``measure_plan`` — compiled on accelerators, interpret on
    CPU) and the plan with the highest measured Mb/s wins. Timings are
    persisted to the disk-backed tune DB (kernels/tunedb.py; pass
    ``tunedb=`` to use a non-default instance) keyed by
    ``DecodePlan.fingerprint()`` x platform identity, so a plan is measured
    once per (hardware, code) pair and every later process — serve, stream,
    benchmarks — reuses the cached timing with zero re-measurement
    (``tunedb_hits`` tracer counters prove it).

    Every call runs under a ``plan_decode`` tracing span whose attributes
    carry the chosen plan (kernel, layout, tile, chunk geometry, block
    decomposition) and the predicted VMEM footprint vs budget — and, under
    ``measure=True``, the measured ms/Mb/s next to the predicted bytes plus
    how many candidates came from cache vs fresh measurement. The trace
    file records *why* the launch geometry is what it is.
    """
    with get_tracer().span("plan_decode") as sp:
        spec.validate()
        bf, ov = resolve_block(trellis, spec, block_frames, overlap)
        plan_spec = spec.blocked(bf, ov) if bf > 1 else spec
        eff_max = (max_frames * bf if (max_frames is not None and bf > 1)
                   else max_frames)
        if frames_per_tile is not None:
            lay, mosaic = _resolve(
                Layout.SUBLANE if layout == "auto" else layout, None)
            model = unified_vmem_bytes if unified else split_vmem_bytes
            total, breakdown = model(
                trellis, plan_spec, frames_per_tile,
                pack_survivors=pack_survivors, radix=radix, layout=lay,
                bm_dtype=bm_dtype, mosaic=mosaic)
            tile = TilePlan(int(frames_per_tile), total, breakdown,
                            vmem_budget, "unified" if unified else "split",
                            lay, str(bm_dtype), mosaic)
        elif layout == "auto":
            plans = [plan_tiles(trellis, plan_spec,
                                pack_survivors=pack_survivors,
                                radix=radix, vmem_budget=vmem_budget,
                                max_frames=eff_max, unified=unified,
                                layout=lay, bm_dtype=bm_dtype, mosaic=True)
                     for lay in (Layout.LANE, Layout.SUBLANE)]
            tile = max(plans, key=lambda p: (p.frames_per_tile, -p.vmem_bytes))
        else:
            tile = plan_tiles(trellis, plan_spec,
                              pack_survivors=pack_survivors,
                              radix=radix, vmem_budget=vmem_budget,
                              max_frames=eff_max, unified=unified,
                              layout=layout, bm_dtype=bm_dtype)
        chunk = (int(chunk_frames) if chunk_frames is not None
                 else 2 * max(1, tile.frames_per_tile // bf) * num_devices)
        plan = DecodePlan(tile, pack_survivors, radix, chunk,
                          num_devices, bf, ov)
        if measure:
            db = tunedb if tunedb is not None else TUNE_DB
            if frames_per_tile is not None:
                candidates = [plan]       # pinned tile: measure + record it
            else:
                candidates = _measure_candidates(
                    trellis, plan_spec, plan, layout=layout, unified=unified,
                    pack_survivors=pack_survivors, radix=radix,
                    bm_dtype=bm_dtype, vmem_budget=vmem_budget,
                    eff_max=eff_max, num_devices=num_devices, bf=bf, ov=ov,
                    chunk_frames=chunk_frames, top_k=measure_top_k)
            plat = platform_id()
            records, fresh = [], 0
            for cand in candidates:
                rec = db.get(cand.fingerprint(), plat)
                if rec is None:
                    rec = measure_plan(trellis, spec, cand,
                                       reps=measure_reps,
                                       frames=measure_frames)
                    db.put(cand.fingerprint(), rec, plat)
                    db.record_measure()
                    fresh += 1
                records.append((cand, rec))
            analytic_fp = plan.fingerprint()
            plan, best = max(records,
                             key=lambda pr: pr[1].get("mbps", 0.0))
            tile = plan.tile
            sp.set(measured_ms=round(float(best["ms"]), 4),
                   measured_mbps=round(float(best["mbps"]), 4),
                   measure_candidates=len(records), measure_new=fresh,
                   measure_cached=len(records) - fresh,
                   analytic_fingerprint=analytic_fp)
        sp.set(kernel=tile.kernel, layout=Layout(tile.layout).value,
               frames_per_tile=tile.frames_per_tile,
               bm_dtype=str(tile.bm_dtype),
               chunk_frames=int(plan.chunk_frames),
               num_devices=int(num_devices), block_frames=int(bf),
               overlap=int(ov), vmem_bytes=tile.vmem_bytes,
               vmem_budget=tile.budget,
               fits=tile.vmem_bytes <= tile.budget,
               fingerprint=plan.fingerprint())
        return plan
