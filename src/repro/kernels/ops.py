"""Jitted public wrappers around the Pallas Viterbi kernels.

Handles frame-count padding to the tile size, selects unified vs split
(forward kernel + separate traceback) execution, resolves the
``frames_per_tile='auto'`` tile plan (kernels/autotune.py — budgeting the
kernel that will actually run), and exposes one call the rest of the
framework uses: ``viterbi_decode_frames``.

Defaults are the library's best-known configuration (bit-packed survivors,
radix-4, autotuned tiles — the same defaults as core.pipeline.DecoderConfig);
pass ``pack_survivors=False, radix=2, frames_per_tile=8`` explicitly to
reproduce the seed kernel behavior.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..core.framed import FrameSpec, merge_blocks, reframe_blocks
from ..core.traceback import parallel_traceback_frames, serial_traceback_frames
from ..core.trellis import Trellis
from ..obs.tracer import get_tracer
from .autotune import plan_tiles
from .packing import Layout
from .viterbi_fwd import forward_frames
from .viterbi_unified import unified_decode_frames

__all__ = ["viterbi_decode_frames"]


def _pad_frames(frames: jax.Array, tile: int):
    F = frames.shape[0]
    Fp = -(-F // tile) * tile
    if Fp != F:
        frames = jnp.pad(frames, ((0, Fp - F), (0, 0), (0, 0)))
    return frames, F


@partial(jax.jit, static_argnames=("trellis", "spec", "unified",
                                   "frames_per_tile", "pack_survivors",
                                   "radix", "layout", "bm_dtype",
                                   "block_frames", "overlap", "interpret"))
def viterbi_decode_frames(frames: jax.Array, trellis: Trellis,
                          spec: FrameSpec, *, unified: bool = True,
                          frames_per_tile: int | str = "auto",
                          pack_survivors: bool = True, radix: int = 4,
                          layout: str = "lane", bm_dtype: str = "float32",
                          block_frames: int = 1, overlap: int = 0,
                          interpret: bool = True) -> jax.Array:
    """(F, L, beta) LLR frames -> (F, f) decoded bits.

    unified=True  : the paper's single-kernel path (survivors in VMEM only).
    unified=False : prior-work baseline — forward kernel streams survivors
                    to HBM, traceback runs as a separate batched step.
    frames_per_tile: frames decoded per kernel grid step, or 'auto' to let
                    the VMEM-budget planner choose (autotune.plan_tiles,
                    budgeting whichever kernel/layout/dtype runs here).
    pack_survivors: bit-pack the survivor array 32x (VMEM scratch for the
                    unified kernel, the HBM stream for the split baseline).
    radix         : 2, or 4 to fuse two trellis stages per ACS/traceback
                    step.
    layout        : 'lane' (frames on sublanes, PR-1 orientation) or
                    'sublane' (Mosaic-native, frames on lanes; the layout
                    whose packing survives hardware lane padding).
    bm_dtype      : 'float32' | 'bfloat16' branch-metric storage. All knob
                    combinations decode bit-identically except bf16, which
                    quantizes the metrics once (BER-neutral to ~1e-3).
    block_frames  : >1 engages intra-frame block-parallel decode
                    (kernels/block.py): each frame re-framed into
                    block_frames blocks of f/B + 2*overlap stages on the
                    frame axis, decoded by this same kernel under the
                    derived spec, merged by truncating each block's
                    overlap. The second knob besides bf16 that is not
                    bit-exact: a truncated-traceback approximation,
                    BER-gated to 1e-3 at overlap ~5*K, and exactly
                    bit-identical when overlap >= block.full_overlap().
    overlap       : per-block training/truncation region (stages); only
                    meaningful with block_frames > 1.
    """
    spec.validate()
    # entry validation (trace-time, so invalid calls fail with a clear
    # message instead of a shape error deep inside a kernel)
    if frames.ndim != 3:
        raise ValueError(
            f"frames must be (F, L, beta), got {frames.ndim}-D "
            f"{frames.shape}")
    if frames.shape[1] != spec.frame_len:
        raise ValueError(
            f"frames.shape[1]={frames.shape[1]} != spec.frame_len="
            f"{spec.frame_len} (v1 + f + v2 overlap window)")
    if frames.shape[2] != trellis.beta:
        raise ValueError(
            f"frames.shape[2]={frames.shape[2]} != trellis.beta="
            f"{trellis.beta} coded bits per stage")
    if not jnp.issubdtype(frames.dtype, jnp.floating):
        raise ValueError(
            f"frames must be floating-point LLRs, got dtype "
            f"{frames.dtype}")
    F_in = frames.shape[0]
    if block_frames < 1:
        raise ValueError(f"block_frames must be >= 1, got {block_frames}")
    if block_frames > 1:
        # intra-frame block-parallel mode: re-frame (F, L) frames into
        # (F*B, f/B + 2*overlap) blocks on the same frame axis and decode
        # them below under the derived spec — the tile planner, padding,
        # kernels and traceback all see ordinary (short) frames
        sub = spec.blocked(block_frames, overlap)
        frames = reframe_blocks(frames, spec, block_frames, overlap)
        spec = sub
    lay = Layout(layout)
    if frames_per_tile == "auto":
        frames_per_tile = plan_tiles(
            trellis, spec, pack_survivors=pack_survivors, radix=radix,
            unified=unified, layout=lay, bm_dtype=bm_dtype,
            max_frames=frames.shape[0]).frames_per_tile
    # serial traceback == one subframe spanning the kept region (DESIGN §2)
    f0 = spec.f0 if spec.parallel_tb else spec.f
    v2s = spec.v2s if spec.parallel_tb else spec.v2
    start = spec.start if spec.parallel_tb else "boundary"

    # This function body runs at jit *trace* time only — so this event
    # marks each real XLA compile of a decode program (re-launches of the
    # cached executable never reach here). One glance at a trace file
    # answers "how many distinct kernels did this run compile, and with
    # which knobs?".
    trace = get_tracer()
    trace.event("kernel_trace", kernel="unified" if unified else "split",
                frames=int(frames.shape[0]),
                frames_per_tile=int(frames_per_tile), layout=lay.value,
                bm_dtype=str(bm_dtype), radix=int(radix),
                pack_survivors=bool(pack_survivors),
                block_frames=int(block_frames), overlap=int(overlap),
                interpret=bool(interpret))
    trace.count("kernel_traces")

    padded, F = _pad_frames(frames, frames_per_tile)
    if unified:
        bits = unified_decode_frames(
            padded, trellis=trellis, v1=spec.v1, f=spec.f, v2=spec.v2,
            f0=f0, v2s=v2s, start=start, frames_per_tile=frames_per_tile,
            pack_survivors=pack_survivors, radix=radix, layout=lay.value,
            bm_dtype=bm_dtype, interpret=interpret)
        bits = bits[:F]
    else:
        sel, amax = forward_frames(padded, trellis=trellis,
                                   frames_per_tile=frames_per_tile,
                                   pack_survivors=pack_survivors, radix=radix,
                                   layout=lay.value, bm_dtype=bm_dtype,
                                   interpret=interpret)
        # HBM round-trip; the sublane stream keeps frames on the trailing
        # axis
        if lay is Layout.SUBLANE:
            sel, amax = sel[..., :F], amax[:F]
        else:
            sel, amax = sel[:F], amax[:F]
        if spec.parallel_tb:
            bits = parallel_traceback_frames(
                sel, amax, trellis, spec.v1, spec.f, spec.f0, spec.v2s,
                spec.start, packed=pack_survivors, layout=lay)
        else:
            bits = serial_traceback_frames(sel, amax, trellis, spec.v1,
                                           spec.f, packed=pack_survivors,
                                           layout=lay)
    if block_frames > 1:
        bits = merge_blocks(bits, block_frames)       # (F_in, f)
        assert bits.shape[0] == F_in
    return bits
