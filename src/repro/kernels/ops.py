"""Jitted public wrappers around the Pallas Viterbi kernels.

Handles frame-count padding to the tile size, selects unified vs split
(forward kernel + separate traceback) execution, resolves the
``frames_per_tile='auto'`` tile plan (kernels/autotune.py), and exposes one
call the rest of the framework uses: ``viterbi_decode_frames``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..core.framed import FrameSpec
from ..core.traceback import parallel_traceback, serial_traceback
from ..core.trellis import Trellis
from .autotune import plan_tiles
from .viterbi_fwd import forward_frames
from .viterbi_unified import unified_decode_frames

__all__ = ["viterbi_decode_frames"]


def _pad_frames(frames: jax.Array, tile: int):
    F = frames.shape[0]
    Fp = -(-F // tile) * tile
    if Fp != F:
        frames = jnp.pad(frames, ((0, Fp - F), (0, 0), (0, 0)))
    return frames, F


@partial(jax.jit, static_argnames=("trellis", "spec", "unified",
                                   "frames_per_tile", "pack_survivors",
                                   "radix", "interpret"))
def viterbi_decode_frames(frames: jax.Array, trellis: Trellis,
                          spec: FrameSpec, *, unified: bool = True,
                          frames_per_tile: int | str = 8,
                          pack_survivors: bool = False, radix: int = 2,
                          interpret: bool = True) -> jax.Array:
    """(F, L, beta) LLR frames -> (F, f) decoded bits.

    unified=True  : the paper's single-kernel path (survivors in VMEM only).
    unified=False : prior-work baseline — forward kernel streams survivors
                    to HBM, traceback runs as a separate (vmapped) step.
    frames_per_tile: frames decoded per kernel grid step, or 'auto' to let
                    the VMEM-budget planner choose (autotune.plan_tiles).
    pack_survivors: bit-pack the survivor array 32x (VMEM scratch for the
                    unified kernel, the HBM stream for the split baseline).
    radix         : 2, or 4 to fuse two trellis stages per ACS/traceback
                    step. All knob combinations decode bit-identically.
    """
    spec.validate()
    if frames_per_tile == "auto":
        frames_per_tile = plan_tiles(
            trellis, spec, pack_survivors=pack_survivors, radix=radix,
            max_frames=frames.shape[0]).frames_per_tile
    # serial traceback == one subframe spanning the kept region (DESIGN §2)
    f0 = spec.f0 if spec.parallel_tb else spec.f
    v2s = spec.v2s if spec.parallel_tb else spec.v2
    start = spec.start if spec.parallel_tb else "boundary"

    padded, F = _pad_frames(frames, frames_per_tile)
    if unified:
        bits = unified_decode_frames(
            padded, trellis=trellis, v1=spec.v1, f=spec.f, v2=spec.v2,
            f0=f0, v2s=v2s, start=start, frames_per_tile=frames_per_tile,
            pack_survivors=pack_survivors, radix=radix, interpret=interpret)
        return bits[:F]

    sel, amax = forward_frames(padded, trellis=trellis,
                               frames_per_tile=frames_per_tile,
                               pack_survivors=pack_survivors, radix=radix,
                               interpret=interpret)
    sel, amax = sel[:F], amax[:F]                    # HBM round-trip
    if spec.parallel_tb:
        tb = lambda s, a: parallel_traceback(s, a, trellis, spec.v1, spec.f,
                                             spec.f0, spec.v2s, spec.start,
                                             packed=pack_survivors)
        return jax.vmap(tb)(sel, amax)
    tb = lambda s, a: serial_traceback(s, trellis, a[-1], spec.v1, spec.f,
                                       packed=pack_survivors)
    return jax.vmap(tb)(sel, amax)
