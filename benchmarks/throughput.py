"""Paper Tables IV & V: decoder throughput, regular vs parallel traceback.

The container has no GPU/TPU; wall-clock numbers are CPU (jitted XLA) and
meaningful as RELATIVE comparisons between the paper's own variants:
  * serial vs parallel traceback        (Table IV vs V: paper sees ~2x)
  * unified vs split (global-memory) survivor-path storage (Table I)
The TPU-side absolute projection comes from the §Roofline analysis instead.
"""
from __future__ import annotations

import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import FrameSpec, STD_K7, framed_decode
from repro.core.framed import frame_llr
from repro.kernels import ops

#: Compiled-mode switch (``--compiled`` / bench_gate's BENCH_COMPILED):
#: False runs the Pallas kernels under the interpreter (the only option
#: on CPU), True compiles them for the real backend — the sections
#: themselves are identical, only ``interpret=`` changes, and the
#: platform stamp on the recorded run keeps the two trajectories apart.
COMPILED = False


def set_compiled(on: bool = True) -> None:
    global COMPILED
    COMPILED = bool(on)


def _interpret() -> bool:
    """interpret= for every kernel launch in this module."""
    return not COMPILED


def _time(fn, *args, reps=3):
    fn(*args).block_until_ready()              # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps


def _time_best(fn, *args, reps=3):
    """Min-of-reps: robust to the cgroup scheduling stalls of shared CPUs
    (a single stall poisons a mean but not a min)."""
    fn(*args).block_until_ready()              # compile + warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def throughput_framed(spec: FrameSpec, n: int = 2_000_000) -> dict:
    """Mb/s of the jitted framed decoder (pure-JAX path, compiled)."""
    rng = np.random.default_rng(0)
    llr = jnp.asarray(rng.standard_normal((n, 2)).astype(np.float32))
    fn = jax.jit(lambda l: framed_decode(l, STD_K7, spec))
    dt = _time(fn, llr)
    return {"us_per_call": dt * 1e6, "mbps": n / dt / 1e6}


def table4(n=1_000_000):
    rows = []
    for v2 in (10, 20, 40):
        for f in (64, 256):
            r = throughput_framed(FrameSpec(f=f, v1=20, v2=v2), n)
            rows.append({"table": "IV", "f": f, "v2": v2, **r})
    return rows


def table5(n=1_000_000):
    rows = []
    for v2 in (25, 45):
        for f0 in (8, 32):
            spec = FrameSpec(f=256, v1=20, v2=v2, f0=f0, v2s=v2)
            r = throughput_framed(spec, n)
            rows.append({"table": "V", "f0": f0, "v2": v2, **r})
    return rows


def unified_vs_split(n=80_000):
    """Table I comparison on the kernel path (interpret mode => relative)."""
    rng = np.random.default_rng(0)
    spec = FrameSpec(f=256, v1=20, v2=45, f0=32, v2s=45)
    llr = jnp.asarray(rng.standard_normal((n, 2)).astype(np.float32))
    frames = frame_llr(llr, spec)
    rows = []
    for unified in (True, False):
        fn = jax.jit(lambda fr: ops.viterbi_decode_frames(
            fr, STD_K7, spec, unified=unified, interpret=_interpret()))
        dt = _time(fn, frames, reps=1)
        rows.append({"table": "I", "variant": "unified" if unified else "split",
                     "us_per_call": dt * 1e6, "mbps": n / dt / 1e6})
    return rows


def kernel_sweep(full: bool = False):
    """Packed x radix x tile-size x layout x bm-dtype sweep.

    The perf-trajectory benchmark for the unified kernel's survivor
    compression (BENCH_kernels.json). The (pack=False, radix=2, ft=8) row
    is the seed kernel; (pack=True, radix=4, ft>=32) is PR-1's optimized
    configuration; the 'sublane' rows are the Mosaic-native layout whose
    packing survives hardware lane padding (their vmem_mosaic_kib column
    is the honest hardware footprint — compare it with the lane rows').
    Interpret mode => relative numbers.
    """
    rng = np.random.default_rng(0)
    spec = FrameSpec(f=256, v1=20, v2=45, f0=32, v2s=45)
    n = (128 if full else 32) * spec.f
    llr = jnp.asarray(rng.standard_normal((n, 2)).astype(np.float32))
    frames = frame_llr(llr, spec)

    from repro.kernels.autotune import plan_tiles, unified_vmem_bytes
    grid = [(False, 2, 8, "lane", "float32"),            # seed configuration
            (False, 4, 8, "lane", "float32"),            # one knob at a time
            (True, 2, 8, "lane", "float32"),
            (True, 4, 8, "lane", "float32"),
            (False, 2, 32, "lane", "float32"),           # deeper tiles
            (True, 4, 32, "lane", "float32"),
            (True, 4, "auto", "lane", "float32"),        # PR-1 autotuned
            (True, 4, 8, "sublane", "float32"),          # Mosaic-native
            (True, 4, 32, "sublane", "float32"),
            (True, 4, "auto", "sublane", "float32"),
            (True, 4, 32, "sublane", "bfloat16")]        # compressed BMs
    rows = []
    for pack, radix, ft, layout, bm_dtype in grid:
        fn = jax.jit(lambda fr, p=pack, r=radix, t=ft, lay=layout,
                     bd=bm_dtype: ops.viterbi_decode_frames(
                         fr, STD_K7, spec, frames_per_tile=t,
                         pack_survivors=p, radix=r, layout=lay, bm_dtype=bd,
                         interpret=_interpret()))
        dt = _time_best(fn, frames, reps=3)
        ft_res = (plan_tiles(STD_K7, spec, pack_survivors=pack, radix=radix,
                             layout=layout, bm_dtype=bm_dtype,
                             max_frames=frames.shape[0]).frames_per_tile
                  if ft == "auto" else ft)
        vmem, _ = unified_vmem_bytes(STD_K7, spec, ft_res,
                                     pack_survivors=pack, radix=radix,
                                     layout=layout, bm_dtype=bm_dtype,
                                     mosaic=False)
        vmem_m, _ = unified_vmem_bytes(STD_K7, spec, ft_res,
                                       pack_survivors=pack, radix=radix,
                                       layout=layout, bm_dtype=bm_dtype,
                                       mosaic=True)
        rows.append({"table": "kernels", "pack": pack, "radix": radix,
                     "ft": ft_res, "auto": ft == "auto", "layout": layout,
                     "bm_dtype": bm_dtype, "n_bits": n, "reps": 3,
                     "vmem_kib": round(vmem / 1024, 1),
                     "vmem_mosaic_kib": round(vmem_m / 1024, 1),
                     "us_per_call": dt * 1e6, "mbps": n / dt / 1e6})
    return rows


def streaming_bench(full: bool = False):
    """Streaming front-end vs single-shot decode on a multi-chunk stream.

    Both run the compiled reference backend (the kernel backends interpret
    on CPU, which would time the interpreter, not the pipeline), and both
    are timed on the same numpy-in -> numpy-out contract a receiver sees
    (the single shot pays its host<->device staging too). The streaming
    rows include all host-side chunking/framing plus the flush, so beating
    single-shot means the double-buffered dispatch more than hides the
    chunk bookkeeping (acceptance: streaming >= single-shot here).
    """
    from repro.core import DecoderConfig, make_decoder
    from repro.core.stream import make_stream_decoder
    rng = np.random.default_rng(0)
    spec = FrameSpec(f=256, v1=20, v2=45, f0=32, v2s=45)
    nframes = 512 if full else 128
    n = nframes * spec.f
    llr = rng.standard_normal((n, 2)).astype(np.float32)
    cfg = DecoderConfig(spec=spec)
    rows = []

    dec = make_decoder(cfg)
    np.asarray(dec(llr, n))                            # compile + warm
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        np.asarray(dec(llr, n))
        best = min(best, time.perf_counter() - t0)
    rows.append({"table": "streaming", "variant": "single_shot",
                 "n_bits": n, "chunk_frames": nframes, "reps": 3,
                 "us_per_call": best * 1e6, "mbps": n / best / 1e6})

    for chunk in (16, 32):
        sdec = make_stream_decoder(cfg, chunk_frames=chunk)

        def run_stream():
            out = [sdec.push(llr[i:i + chunk * spec.f])
                   for i in range(0, n, chunk * spec.f)]
            out.append(sdec.flush())
            return sum(o.size for o in out)

        assert run_stream() == n                   # warm every chunk shape
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            nbits = run_stream()
            best = min(best, time.perf_counter() - t0)
            assert nbits == n
        rows.append({"table": "streaming",
                     "variant": f"stream_chunk{chunk}", "n_bits": n,
                     "chunk_frames": chunk, "reps": 3,
                     "us_per_call": best * 1e6, "mbps": n / best / 1e6})
    return rows


def _serve_workload(full: bool):
    """Session mix + pre-cut raw chunk streams shared by serve_bench and
    serve_faults_bench: 8 (full: 16) sessions across three code configs —
    K=7 rate-1/2, K=7 rate-3/4 (raw punctured push), K=5 rate-1/2 —
    pushing one chunk per session per round. Returns
    (streams, total_bits, nbuckets, C, nchunks, nsess) where streams is
    [(cfg, [chunk0, chunk1, ...], n_bits), ...]."""
    from repro.core import DecoderConfig
    from repro.core.puncture import PATTERNS
    from repro.core.trellis import make_trellis

    C = 16                                     # chunk frames per session
    nchunks = 24 if full else 6
    nsess = 16 if full else 8
    k5 = make_trellis(5, (0o23, 0o35))
    spec12 = FrameSpec(f=64, v1=16, v2=20, f0=16, v2s=20)
    spec34 = FrameSpec(f=63, v1=21, v2=21, f0=21, v2s=21)
    cfgs = [DecoderConfig(spec=spec12),                   # K7 1/2
            DecoderConfig(spec=spec34, rate="3/4"),       # K7 punctured
            DecoderConfig(trellis=k5, spec=spec12)]       # K5 1/2
    # half the sessions on the main code, the rest split across the other
    # two — every bucket sees real batching (4/2/2 at nsess=8)
    mix = ([cfgs[0]] * (nsess // 2) + [cfgs[1]] * (nsess // 4)
           + [cfgs[2]] * (nsess - nsess // 2 - nsess // 4))

    rng = np.random.default_rng(0)
    streams = []                               # (cfg, raw chunks, n_bits)
    for cfg in mix:
        n = C * cfg.spec.f * nchunks           # stages == bits
        if cfg.rate != "1/2":
            pat = PATTERNS[cfg.rate]
            m = n * pat.sum() // pat.shape[1]  # raw punctured symbols
            raw = rng.standard_normal(m).astype(np.float32)
            per = m // nchunks
        else:
            raw = rng.standard_normal((n, 2)).astype(np.float32)
            per = n // nchunks
        streams.append((cfg, [raw[i * per:(i + 1) * per]
                              for i in range(nchunks)], n))
    total_bits = sum(n for _, _, n in streams)
    nbuckets = len({(cfg.trellis, cfg.spec) for cfg, _, _ in streams})
    return streams, total_bits, nbuckets, C, nchunks, nsess


def serve_bench(full: bool = False):
    """Multi-tenant serve trajectory: sessions x codes sweep.

    The _serve_workload mix decoded (a) by N independent StreamDecoders
    and (b) by one DecodeServer batching each bucket's windows into
    single launches. Both run the compiled reference backend on identical
    arrival patterns (one chunk per session per round), so the delta is
    purely dispatch aggregation: the server wins when one
    (slots*C)-frame launch beats `slots` C-frame launches. Aggregate
    Mb/s is total decoded bits over wall time; the server rows carry the
    per-bucket latency/occupancy metrics and the plan-cache trace count
    (the serve acceptance criterion: server >= independent, one compile
    per bucket shape).
    """
    from repro.core import make_stream_decoder
    from repro.serve import DecodeServer, PlanCache

    streams, total_bits, nbuckets, C, nchunks, nsess = _serve_workload(full)

    def run_independent():
        decs = [make_stream_decoder(cfg, chunk_frames=C)
                for cfg, _, _ in streams]
        got = 0
        for r in range(nchunks):
            for dec, (_, chunks, _) in zip(decs, streams):
                got += dec.push(chunks[r]).size
        for dec in decs:
            got += dec.flush().size
        return got

    cache = PlanCache()

    def run_server():
        srv = DecodeServer(slots=4, max_sessions=2 * nsess, cache=cache)
        sids = [srv.open_session(cfg, chunk_frames=C)
                for cfg, _, _ in streams]
        got = 0
        for r in range(nchunks):
            for sid, (_, chunks, _) in zip(sids, streams):
                srv.push(sid, chunks[r])
            while srv.step():                  # drain queues, stay async
                pass
            for sid in sids:
                got += srv.poll(sid).size      # non-blocking collect
        for sid in sids:
            got += srv.close_session(sid).size
        return got, srv

    rows = []
    assert run_independent() >= total_bits     # warm every chunk shape
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        nbits = run_independent()
        best = min(best, time.perf_counter() - t0)
    rows.append({"table": "serve", "variant": "independent",
                 "sessions": nsess, "codes": 3, "buckets": nbuckets,
                 "chunk_frames": C, "n_bits": total_bits, "reps": 3,
                 "us_per_call": best * 1e6, "mbps": total_bits / best / 1e6})

    nbits, _ = run_server()                    # warm (and count compiles)
    assert nbits >= total_bits
    best, srv = float("inf"), None
    for _ in range(3):
        t0 = time.perf_counter()
        nbits, srv = run_server()
        best = min(best, time.perf_counter() - t0)
        assert nbits >= total_bits
    tot = srv.metrics.totals()
    rows.append({"table": "serve", "variant": "server",
                 "sessions": nsess, "codes": 3, "buckets": nbuckets,
                 "chunk_frames": C, "slots": 4, "n_bits": total_bits,
                 "reps": 3, "us_per_call": best * 1e6,
                 "mbps": total_bits / best / 1e6,
                 "p50_ms": round(tot["p50_ms"], 3),
                 "p99_ms": round(tot["p99_ms"], 3),
                 "occupancy": round(tot["occupancy"], 4),
                 "launches": tot["launches"],
                 "plan_traces": cache.stats()["traces"]})
    return rows


def serve_faults_bench(full: bool = False):
    """Serve throughput under injected launch faults (the
    'serve_under_faults' trajectory section).

    Same workload and server geometry as serve_bench's "server" variant,
    with a seeded FaultInjector raising a kernel exception on 1% of
    launches plus every 16th deterministically (the `every` term
    guarantees the retry path actually runs in the quick CI workload,
    where 1% of ~20 launches would usually round to zero). Every failed
    launch is retried with zero backoff on the warm plan cache, so the
    row measures the price of fault recovery itself: dispatch + failed
    attempt + redispatch. The run must still deliver every bit. The
    regression gate tracks this row's mbps like the clean serve row.
    """
    from repro.serve import DecodeServer, PlanCache
    from repro.testing import FaultInjector, FaultSpec

    streams, total_bits, nbuckets, C, nchunks, nsess = _serve_workload(full)
    cache = PlanCache()

    def run_server(faults):
        srv = DecodeServer(slots=4, max_sessions=2 * nsess, cache=cache,
                           max_retries=3, backoff_s=0.0, faults=faults)
        sids = [srv.open_session(cfg, chunk_frames=C)
                for cfg, _, _ in streams]
        got = 0
        for r in range(nchunks):
            for sid, (_, chunks, _) in zip(sids, streams):
                srv.push(sid, chunks[r])
            while srv.step():
                pass
            for sid in sids:
                got += srv.poll(sid).size
        for sid in sids:
            got += srv.close_session(sid).size
        return got, srv

    nbits, _ = run_server(None)                # warm/compile fault-free
    assert nbits >= total_bits
    best, srv, inj = float("inf"), None, None
    for _ in range(3):
        # fresh injector, same seed: identical fault schedule every rep
        # (and every PR), so the mbps trajectory is comparable
        faults = FaultInjector(
            FaultSpec("launch_error", p=0.01, every=16), seed=11)
        t0 = time.perf_counter()
        nbits, this_srv = run_server(faults)
        dt = time.perf_counter() - t0
        assert nbits >= total_bits             # full recovery, always
        if dt < best:
            best, srv, inj = dt, this_srv, faults
    tot = srv.metrics.totals()
    assert tot["launch_errors"] == inj.injected["launch_error"]
    return [{"table": "serve_faults", "variant": "server_faults",
             "sessions": nsess, "codes": 3, "buckets": nbuckets,
             "chunk_frames": C, "slots": 4, "n_bits": total_bits,
             "reps": 3, "us_per_call": best * 1e6,
             "mbps": total_bits / best / 1e6,
             "injected": int(inj.injected["launch_error"]),
             "launch_errors": tot["launch_errors"],
             "retries": tot["retries"], "degraded": tot["degraded"],
             "p99_ms": round(tot["p99_ms"], 3),
             "health": tot["health"]}]


def block_bench(full: bool = False):
    """Intra-frame block-parallel decode vs the sequential single-scan
    plan on a FEW-long-frames workload (the 'block' trajectory section).

    A handful of f=4096 frames — the latency scenario block mode exists
    for (one long serve window, not a deep batch) — decoded by the same
    unified kernel twice under the same VMEM budget. The sequential
    variant scans all v1+f+v2 stages per grid step and cannot fill even
    the minimum 8-frame tile, so most of its per-step width is padding;
    the blocked variant lets resolve_block split each frame into ~32
    blocks of f/B + 2*overlap stages laid out on the frame axis, which
    fill a wide tile exactly — the tentpole mechanism ("a single long
    frame fills a tile the way many short frames do"). Interpret mode =>
    relative numbers; the acceptance criterion (blocked >= 1.5x
    sequential at L >= 4096, equal VMEM budget) is asserted here so the
    trajectory can never silently record a regressed decomposition.
    """
    from repro.kernels.block import resolve_block
    rng = np.random.default_rng(0)
    spec = FrameSpec(f=4096, v1=32, v2=32, f0=32, v2s=32)
    nframes = 4 if full else 2
    n = nframes * spec.f
    llr = jnp.asarray(rng.standard_normal((n, 2)).astype(np.float32))
    frames = frame_llr(llr, spec)
    bf, ov = resolve_block(STD_K7, spec, "auto", None)
    assert bf > 1, "auto policy must engage at f=4096"

    rows = []
    by_variant = {}
    for variant, B, o in (("sequential", 1, 0), ("blocked", bf, ov)):
        fn = jax.jit(lambda fr, B=B, o=o: ops.viterbi_decode_frames(
            fr, STD_K7, spec, frames_per_tile="auto", layout="sublane",
            block_frames=B, overlap=o, interpret=_interpret()))
        dt = _time_best(fn, frames, reps=2)
        mbps = n / dt / 1e6
        by_variant[variant] = mbps
        rows.append({"table": "block", "variant": variant, "f": spec.f,
                     "block_frames": B, "overlap": o, "n_bits": n,
                     "reps": 2, "us_per_call": dt * 1e6, "mbps": mbps})
    ratio = by_variant["blocked"] / by_variant["sequential"]
    if not COMPILED:
        # the interpret-mode win comes from tile fill; on real hardware
        # the blocked-vs-sequential trade-off is exactly what the compiled
        # trajectory exists to MEASURE (ROADMAP item 1 follow-on), so the
        # ratio is recorded there, not asserted
        assert ratio >= 1.5, (
            f"acceptance criterion failed: block-parallel decode is only "
            f"{ratio:.2f}x the sequential-scan plan at f={spec.f} (needs "
            f">= 1.5x at equal VMEM budget)")
    return rows


#: Offered-load levels of the serve_load section. Fixed: the regression
#: gate compares stored p99s per level, so the levels are part of the
#: trajectory contract (ROADMAP item 4's "p99 vs offered load at
#: 64/256/1024 sessions").
LOAD_LEVELS = (64, 256, 1024)


def serve_load_sweep(full: bool = False):
    """Tail-latency-under-load SLO curves (the 'serve_load' section).

    One code config, ``LOAD_LEVELS`` sessions each pushing one C-frame
    chunk per round against a fixed-capacity server (16 slots), so rising
    session count IS rising offered load: at 64 sessions a round drains
    in 4 launches, at 1024 it takes 64 and late windows queue behind
    early ones. Each level records p50/p99 queue-wait (the PR 7
    ``queue_wait_ms`` stage histogram — time from push to batch-pack) and
    p50/p99 end-to-end window latency (push to materialized bits) from a
    fresh server per rep; of ``reps`` runs the one with the LOWEST p99 is
    kept — the min-of-reps discipline applied to a latency metric, since
    scheduler stalls on a shared runner only ever inflate the tail. The
    plan cache is shared across levels and reps (the batch shape
    ``slots x C`` frames never changes), so rep 1 is the only compile.

    The regression gate enforces these rows INVERTED vs the throughput
    sections: p99 above (1 + tol) x the best stored comparable p99
    fails the gate.
    """
    from repro.core import DecoderConfig
    from repro.serve import DecodeServer, PlanCache

    C = 2                                      # chunk frames per push
    spec = FrameSpec(f=64, v1=16, v2=20, f0=16, v2s=20)
    cfg = DecoderConfig(spec=spec)
    rounds = 4 if full else 2
    reps = 2
    slots = 16
    cache = PlanCache()
    rng = np.random.default_rng(0)
    chunk = rng.standard_normal((C * spec.f, 2)).astype(np.float32)

    rows = []
    for nsess in LOAD_LEVELS:
        total_bits = nsess * rounds * C * spec.f

        def run(nsess=nsess):
            srv = DecodeServer(slots=slots, max_sessions=nsess,
                               cache=cache)
            sids = [srv.open_session(cfg, chunk_frames=C)
                    for _ in range(nsess)]
            got = 0
            for _ in range(rounds):
                for sid in sids:
                    srv.push(sid, chunk)
                while srv.step():
                    pass
                for sid in sids:
                    got += srv.poll(sid).size
            for sid in sids:
                got += srv.close_session(sid).size
            return got, srv

        nbits, _ = run()                       # warm the shared plan cache
        assert nbits == total_bits
        best = None
        for _ in range(reps):
            t0 = time.perf_counter()
            nbits, srv = run()
            dt = time.perf_counter() - t0
            assert nbits == total_bits
            tot = srv.metrics.totals()
            if best is None or tot["p99_ms"] < best[0]:
                qw = srv.metrics.stage("queue_wait_ms")
                best = (tot["p99_ms"], dt, tot,
                        (qw.percentile(50), qw.percentile(99)))
        _, dt, tot, (q50, q99) = best
        rows.append({"table": "serve_load", "variant": f"sessions{nsess}",
                     "sessions": nsess, "slots": slots, "chunk_frames": C,
                     "rounds": rounds, "n_bits": total_bits, "reps": reps,
                     "mbps": total_bits / dt / 1e6,
                     "queue_p50_ms": round(q50, 3),
                     "queue_p99_ms": round(q99, 3),
                     "p50_ms": round(tot["p50_ms"], 3),
                     "p99_ms": round(tot["p99_ms"], 3),
                     "launches": tot["launches"],
                     "occupancy": round(tot["occupancy"], 4)})
    return rows


def plan_rows():
    """Tile plans across layouts/models at the default 2 MiB budget — the
    BENCH_kernels.json record behind the layout acceptance criterion
    (sublane-major fits >= 2x the frames per tile of the PR-1 plan under
    honest hardware accounting)."""
    from repro.kernels.autotune import plan_tiles
    spec = FrameSpec(f=256, v1=20, v2=45, f0=32, v2s=45)
    entries = [
        ("lane_logical_pr1", dict(layout="lane", mosaic=False)),
        ("lane_mosaic", dict(layout="lane", mosaic=True)),
        ("sublane_mosaic", dict(layout="sublane")),
        ("sublane_mosaic_bf16", dict(layout="sublane",
                                     bm_dtype="bfloat16")),
        ("split_lane_logical", dict(layout="lane", mosaic=False,
                                    unified=False)),
    ]
    rows = []
    for name, kw in entries:
        p = plan_tiles(STD_K7, spec, pack_survivors=True, radix=4, **kw)
        rows.append({"table": "plans", "plan": name,
                     "kernel": p.kernel, "layout": p.layout.value,
                     "bm_dtype": p.bm_dtype, "mosaic": p.mosaic,
                     "ft": p.frames_per_tile,
                     "vmem_kib": round(p.vmem_bytes / 1024, 1),
                     "budget_kib": round(p.budget / 1024, 1),
                     "fits": p.vmem_bytes <= p.budget})
    return rows


#: Every runnable bench section, by the name the ``--sections`` CLI
#: filter (and CI smoke jobs) selects it with. Each entry takes ``full``.
SECTIONS = {
    "table4": lambda full: table4(4_000_000 if full else 1_000_000),
    "table5": lambda full: table5(4_000_000 if full else 1_000_000),
    "unified_vs_split": lambda full: unified_vs_split(),
    "kernels": kernel_sweep,
    "streaming": streaming_bench,
    "serve": serve_bench,
    "serve_faults": serve_faults_bench,
    "serve_load": serve_load_sweep,
    "plans": lambda full: plan_rows(),
    "block": block_bench,
}

#: The historical default — what plain ``python benchmarks/throughput.py``
#: has always printed (paper Tables IV/V + the Table I comparison).
DEFAULT_SECTIONS = "table4,table5,unified_vs_split"

#: What ``--compiled`` runs when ``--sections`` is not given: the
#: trajectory sections whose compiled-mode numbers ROADMAP item 3 wants,
#: i.e. the same sweep the interpret gate records — directly comparable
#: modulo the platform stamp.
COMPILED_SECTIONS = "kernels,streaming,serve,block"


def main(full: bool = False, sections: str = DEFAULT_SECTIONS):
    rows = []
    for name in sections.split(","):
        rows += SECTIONS[name.strip()](full)
    for r in rows:
        print(",".join(f"{k}={v}" if not isinstance(v, float)
                       else f"{k}={v:.2f}" for k, v in r.items()))
    return rows


def _cli(argv=None):
    import argparse
    ap = argparse.ArgumentParser(
        description="decoder throughput benches (paper Tables IV/V, "
                    "unified-vs-split, kernel sweep, streaming, serve, "
                    "block-parallel)")
    ap.add_argument("--full", action="store_true",
                    help="4M-bit workload instead of the 1M-bit quick run")
    ap.add_argument("--sections", default=DEFAULT_SECTIONS,
                    help=f"comma-separated subset of "
                         f"{','.join(SECTIONS)} to run (so a CI smoke "
                         f"job can run one section alone); default: "
                         f"{DEFAULT_SECTIONS}")
    ap.add_argument("--trace-out", metavar="PATH",
                    help="record the bench under the obs tracer and write "
                         "a Chrome trace-event JSON (each section runs as "
                         "one span; plan_decode/kernel_trace events show "
                         "what compiled)")
    ap.add_argument("--compiled", action="store_true",
                    help="compile the Pallas kernels for the real backend "
                         "instead of interpreting them (benchmarks/"
                         "compiled.py sets the platform + XLA flags; "
                         "BENCH_PLATFORM forces a backend). On a CPU-only "
                         "machine this prints a notice and exits 0 — "
                         "there is nothing honest to record")
    args = ap.parse_args(argv)
    if args.compiled and args.sections == DEFAULT_SECTIONS:
        args.sections = COMPILED_SECTIONS
    names = [s.strip() for s in args.sections.split(",") if s.strip()]
    unknown = [s for s in names if s not in SECTIONS]
    if unknown:
        ap.error(f"unknown section(s) {unknown}; choose from "
                 f"{sorted(SECTIONS)}")
    if not names:
        ap.error("--sections selected nothing")
    if args.compiled:
        try:                       # script (benchmarks/ on path) or package
            import compiled as _compiled
        except ImportError:
            from benchmarks import compiled as _compiled
        backend = _compiled.set_platform(os.environ.get("BENCH_PLATFORM"))
        if backend == "cpu":
            print("compiled mode: no accelerator backend available — "
                  "skipped (interpret-CPU numbers are the default run; "
                  "a 'compiled' point here would really be the "
                  "interpreter)")
            return []
        set_compiled(True)
        print(f"compiled mode: backend {backend!r}")
    if not args.trace_out:
        return main(full=args.full, sections=",".join(names))

    from repro.obs import Tracer, set_tracer, write_chrome_trace
    tracer = Tracer()
    set_tracer(tracer)
    try:
        rows = []
        for name in names:
            with tracer.span(f"bench:{name}") as sp:
                section = SECTIONS[name](args.full)
                sp.set(rows=len(section))
            rows += section
        for r in rows:
            print(",".join(f"{k}={v}" if not isinstance(v, float)
                           else f"{k}={v:.2f}" for k, v in r.items()))
    finally:
        set_tracer(None)
    obj = write_chrome_trace(tracer, args.trace_out)
    print(f"trace: {len(obj['traceEvents'])} events -> {args.trace_out}")
    return rows


if __name__ == "__main__":
    _cli()
