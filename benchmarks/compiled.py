"""Compiled-mode benchmark support: platform selection + XLA flags.

Every trajectory point recorded so far is interpret-mode on a shared CPU
(~1 Mb/s); the paper's regime is compiled kernels on real hardware
(Gb/s). This module is the switch between the two worlds: it configures
JAX for whatever real backend the machine has (the platform/XLA-flag
idiom of the bayespec exemplar in SNIPPETS.md — ``jax_platform_name``
config plus the GPU latency-hiding XLA flags) and reports whether an
accelerator actually exists, so ``throughput.py --compiled`` and
``bench_gate.py`` (``BENCH_COMPILED=1``) can no-op gracefully — exit 0
with a clear notice — on CPU-only runners instead of recording a
"compiled" point that is really the interpreter.

Compiled runs need no schema of their own: every trajectory run is
stamped with ``trajectory.platform()`` (backend + device kind +
jax_version) and the regression gate only compares same-platform runs,
so a GPU trajectory and the interpret-CPU trajectory live side by side
in one BENCH_kernels.json and gate independently.
"""
from __future__ import annotations

import os

__all__ = ["GPU_XLA_FLAGS", "set_platform", "accelerator"]

#: XLA flags for compiled GPU benching (the bayespec exemplar set):
#: triton fusion/gemm, async collectives, and the latency-hiding
#: scheduler — the knobs that matter for launch-bound kernels like a
#: per-stage trellis scan. Applied via ``os.environ.setdefault`` so a
#: user's explicit XLA_FLAGS always wins.
GPU_XLA_FLAGS = " ".join((
    "--xla_gpu_enable_triton_softmax_fusion=true",
    "--xla_gpu_triton_gemm_any=True",
    "--xla_gpu_enable_async_collectives=true",
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
))


def set_platform(platform: str | None = None) -> str:
    """Configure JAX for compiled benchmarking and return the backend
    that is actually in effect.

    ``platform`` forces a backend (``'gpu'``/``'tpu'``/``'cpu'``, e.g.
    from ``BENCH_PLATFORM``); None lets JAX pick its default — a real
    accelerator when one exists, else CPU. For GPU targets the XLA
    flags must land in the environment BEFORE the backend initializes,
    so call this before any jax array op (the benchmark CLIs call it
    first thing in compiled mode)."""
    if platform in ("gpu", "cuda"):
        os.environ.setdefault("XLA_FLAGS", GPU_XLA_FLAGS)
    import jax
    if platform:
        jax.config.update("jax_platform_name", platform)
    return jax.default_backend()


def accelerator() -> str | None:
    """The real-hardware backend name (``'gpu'``/``'tpu'``/...), or None
    when only CPU is available — the "should compiled mode run at all?"
    predicate."""
    import jax
    backend = jax.default_backend()
    return None if backend == "cpu" else backend
