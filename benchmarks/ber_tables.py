"""Paper Tables II & III: Eb/N0-distance-to-theory metric over (f, v2) for
the regular decoder and (f0, v2) for the parallel-traceback decoder.

The paper sweeps a wider grid at higher n; defaults here are sized for the
CPU container (--full widens). The FINDING being reproduced: v2 dominates
BER; f/f0 are second-order; parallel traceback needs larger v2 (~45).
"""
from __future__ import annotations

import numpy as np
import jax

from repro.core import FrameSpec, STD_K7, framed_decode
from repro.channel.sim import ebn0_distance_metric, simulate

EBN0_GRID = (2.0, 2.5, 3.0)


def distance_for(spec: FrameSpec, n: int = 120_000) -> float:
    dec = lambda l: framed_decode(l, STD_K7, spec)
    bers = [simulate(jax.random.PRNGKey(7), n, e, dec)[0]
            for e in EBN0_GRID]
    return ebn0_distance_metric(np.array(EBN0_GRID), np.array(bers))


def table2(fs=(64, 256), v2s=(8, 20, 32), n=120_000):
    rows = []
    for v2 in v2s:
        for f in fs:
            d = distance_for(FrameSpec(f=f, v1=20, v2=v2), n)
            rows.append({"table": "II", "f": f, "v2": v2, "dist_db": d})
    return rows


def table3(f0s=(16, 32), v2s=(20, 45), n=120_000, f=256):
    rows = []
    for v2 in v2s:
        for f0 in f0s:
            spec = FrameSpec(f=f, v1=20, v2=v2, f0=f0, v2s=v2)
            d = distance_for(spec, n)
            rows.append({"table": "III", "f0": f0, "v2": v2, "dist_db": d})
    return rows


def fig11(n=120_000):
    """Start-state strategies (paper Fig. 11)."""
    rows = []
    for start in ("boundary", "fixed"):
        spec = FrameSpec(f=256, v1=20, v2=45, f0=32, v2s=45, start=start)
        rows.append({"table": "fig11", "start": start,
                     "dist_db": distance_for(spec, n)})
    return rows


def main(full: bool = False):
    n = 400_000 if full else 120_000
    rows = table2(n=n) + table3(n=n) + fig11(n=n)
    for r in rows:
        print(",".join(f"{k}={v}" if not isinstance(v, float)
                       else f"{k}={v:.3f}" for k, v in r.items()))
    return rows


if __name__ == "__main__":
    main()
