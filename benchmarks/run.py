"""Benchmark driver — one section per paper table/figure + model zoo.

Prints ``name,us_per_call,derived`` CSV rows (derived = the table's natural
metric: Mb/s for throughput tables, dB-to-theory for BER tables,
tokens/s for the model zoo).

  PYTHONPATH=src python -m benchmarks.run [--full] [--only SECTION]

The ``kernels`` section additionally persists its rows to
``BENCH_kernels.json`` (cwd) — the perf-trajectory datapoint for the
survivor-compression work; diff it across PRs.
"""
from __future__ import annotations

import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-sized grids (slow)")
    ap.add_argument("--only", default=None,
                    choices=["throughput", "kernels", "ber", "models"])
    args = ap.parse_args()

    from . import ber_tables, models_bench, throughput

    print("name,us_per_call,derived")
    if args.only in (None, "kernels"):
        rows = throughput.kernel_sweep(full=args.full)
        for r in rows:
            name = (f"kern_pack{int(r['pack'])}_radix{r['radix']}_"
                    f"ft{r['ft']}" + ("_auto" if r["auto"] else ""))
            print(f"{name},{r['us_per_call']:.1f},{r['mbps']:.2f}Mbps")
        with open("BENCH_kernels.json", "w") as fh:
            # workload metadata: cross-PR diffs are only meaningful when
            # these match (sweep timing reps live in throughput.kernel_sweep)
            json.dump({"schema": "kernel_sweep/v1", "full": args.full,
                       "rows": rows}, fh, indent=1, sort_keys=True)
            fh.write("\n")
    if args.only in (None, "throughput"):
        for r in throughput.main(full=args.full):
            name = f"tput_{r['table']}_" + "_".join(
                f"{k}{v}" for k, v in r.items()
                if k in ("f", "v2", "f0", "variant"))
            print(f"{name},{r['us_per_call']:.1f},{r['mbps']:.2f}Mbps")
    if args.only in (None, "ber"):
        for r in ber_tables.main(full=args.full):
            name = f"ber_{r['table']}_" + "_".join(
                f"{k}{v}" for k, v in r.items()
                if k in ("f", "v2", "f0", "start"))
            print(f"{name},0,{r['dist_db']:.3f}dB")
    if args.only in (None, "models"):
        for r in models_bench.main():
            print(f"model_{r['arch']},{r['us_per_call']:.0f},"
                  f"{r['tokens_per_s']:.0f}tok/s")


if __name__ == "__main__":
    main()
