"""Benchmark driver — one section per paper table/figure + model zoo.

Prints ``name,us_per_call,derived`` CSV rows (derived = the table's natural
metric: Mb/s for throughput tables, dB-to-theory for BER tables,
tokens/s for the model zoo).

  PYTHONPATH=src python -m benchmarks.run [--full] [--only SECTION]

The ``kernels`` section additionally persists its rows to
``BENCH_kernels.json`` (cwd) — the perf-trajectory datapoint for the
survivor-compression work; diff it across PRs.
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-sized grids (slow)")
    ap.add_argument("--only", default=None,
                    choices=["throughput", "kernels", "ber", "models"])
    args = ap.parse_args()

    from . import ber_tables, models_bench, throughput

    print("name,us_per_call,derived")
    if args.only in (None, "kernels"):
        from .trajectory import append_run
        rows = throughput.kernel_sweep(full=args.full)
        for r in rows:
            name = (f"kern_pack{int(r['pack'])}_radix{r['radix']}_"
                    f"ft{r['ft']}_{r['layout']}"
                    + ("_bf16" if r["bm_dtype"] == "bfloat16" else "")
                    + ("_auto" if r["auto"] else ""))
            print(f"{name},{r['us_per_call']:.1f},{r['mbps']:.2f}Mbps")
        stream_rows = throughput.streaming_bench(full=args.full)
        for r in stream_rows:
            print(f"stream_{r['variant']},{r['us_per_call']:.1f},"
                  f"{r['mbps']:.2f}Mbps")
        serve_rows = throughput.serve_bench(full=args.full)
        for r in serve_rows:
            print(f"serve_{r['variant']}_s{r['sessions']},"
                  f"{r['us_per_call']:.1f},{r['mbps']:.2f}Mbps")
        plans = throughput.plan_rows()
        for r in plans:
            print(f"plan_{r['plan']},0,ft{r['ft']}@{r['vmem_kib']}KiB")
        # workload metadata: cross-PR diffs are only meaningful when
        # these match (sweep timing reps live in throughput.kernel_sweep);
        # runs APPEND to BENCH_kernels.json — the per-PR trajectory the
        # regression gate (scripts/bench_gate.py) checks against.
        append_run({"full": args.full, "rows": rows,
                    "streaming": stream_rows, "serve": serve_rows,
                    "plans": plans})
    if args.only in (None, "throughput"):
        for r in throughput.main(full=args.full):
            name = f"tput_{r['table']}_" + "_".join(
                f"{k}{v}" for k, v in r.items()
                if k in ("f", "v2", "f0", "variant"))
            print(f"{name},{r['us_per_call']:.1f},{r['mbps']:.2f}Mbps")
    if args.only in (None, "ber"):
        for r in ber_tables.main(full=args.full):
            name = f"ber_{r['table']}_" + "_".join(
                f"{k}{v}" for k, v in r.items()
                if k in ("f", "v2", "f0", "start"))
            print(f"{name},0,{r['dist_db']:.3f}dB")
    if args.only in (None, "models"):
        for r in models_bench.main():
            print(f"model_{r['arch']},{r['us_per_call']:.0f},"
                  f"{r['tokens_per_s']:.0f}tok/s")


if __name__ == "__main__":
    main()
