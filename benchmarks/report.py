"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the JSON rows
produced by repro.launch.dryrun.

  PYTHONPATH=src python -m benchmarks.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dirname: str):
    rows = []
    for p in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(p) as f:
            rows.append(json.load(f))
    return rows


def _f(x, fmt="{:.3g}"):
    return fmt.format(x) if isinstance(x, (int, float)) and x is not None \
        else "-"


def roofline_table(rows, mesh="16x16"):
    out = ["| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | bound | "
           "useful | MFU bound |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != mesh or r.get("tag"):
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {_f(r['t_compute_s'])} | "
            f"{_f(r['t_memory_s'])} | {_f(r['t_collective_s'])} | "
            f"{r['bottleneck']} | {_f(r.get('useful_ratio'))} | "
            f"{_f(r.get('mfu_bound'))} |")
    return "\n".join(out)


def dryrun_table(rows):
    out = ["| arch | shape | mesh | compile (s) | GFLOP/chip | GB/chip (HBM) "
           "| GB/chip (links) | mem_analysis (GiB) |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("tag"):
            continue
        mem = r.get("peak_memory_per_chip")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{_f(r['t_compile_s'], '{:.0f}')} | "
            f"{_f(r['flops_per_chip']/1e9, '{:.1f}')} | "
            f"{_f(r['bytes_per_chip']/1e9, '{:.1f}')} | "
            f"{_f(r['coll_bytes_per_chip']/1e9, '{:.1f}')} | "
            f"{_f(mem/2**30 if mem else None, '{:.1f}')} |")
    return "\n".join(out)


def opt_table(rows):
    """Baseline vs final-optimized (tag=_opt) MFU bound, single-pod."""
    base = {(r["arch"], r["shape"]): r for r in rows
            if r["mesh"] == "16x16" and not r.get("tag")}
    opt = {(r["arch"], r["shape"]): r for r in rows
           if r["mesh"] == "16x16" and r.get("tag") == "_opt"}
    out = ["| arch | shape | bound (base→opt) | MFU bound base | opt | x |",
           "|---|---|---|---|---|---|"]
    for key in sorted(base):
        if key not in opt:
            continue
        b, o = base[key], opt[key]
        mb, mo = b.get("mfu_bound") or 0, o.get("mfu_bound") or 0
        ratio = mo / mb if mb else float("nan")
        out.append(f"| {key[0]} | {key[1]} | {b['bottleneck']}→"
                   f"{o['bottleneck']} | {_f(mb)} | {_f(mo)} | "
                   f"{_f(ratio, '{:.2f}')} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    rows = load(args.dir)
    for r in rows:
        # tag rows (perf variants) are excluded from the baseline tables
        r.setdefault("tag", "")
    print("## Dry-run (all cells)\n")
    print(dryrun_table(rows))
    print("\n## Roofline (single-pod)\n")
    print(roofline_table(rows, args.mesh))
    print("\n## Multi-pod (2x16x16) compile pass\n")
    print(roofline_table(rows, "2x16x16"))
    print("\n## Baseline vs optimized (strategy=fsdp, fused MoE dispatch, "
          "flash-VJP attention, cf=1.25)\n")
    print(opt_table(rows))


if __name__ == "__main__":
    main()
