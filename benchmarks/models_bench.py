"""Per-arch train/decode step timing on reduced configs (CPU wall clock;
relative numbers). One row per assigned architecture."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.optim import adamw, constant
from repro.train import make_train_step


def _batch(cfg, B=2, S=32):
    b = {"tokens": jnp.ones((B, S), jnp.int32),
         "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.family == "encdec":
        b["frames"] = jnp.ones((B, S, cfg.d_model), jnp.float32)
    if cfg.vision_patches:
        b["vision_embeds"] = jnp.ones((B, cfg.vision_patches, cfg.d_model),
                                      jnp.float32)
    return b


def bench_arch(arch: str, reps: int = 5) -> dict:
    cfg = get_config(arch, reduced=True)
    m = build_model(cfg)
    opt = adamw(constant(1e-3))
    params = m.init(jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(m, opt))
    b = _batch(cfg)
    p, o, met = step(params, opt.init(params), b)      # compile
    jax.block_until_ready(met["loss"])
    t0 = time.perf_counter()
    for _ in range(reps):
        p, o, met = step(p, o, b)
    jax.block_until_ready(met["loss"])
    dt = (time.perf_counter() - t0) / reps
    B, S = b["tokens"].shape
    return {"arch": arch, "us_per_call": dt * 1e6,
            "tokens_per_s": B * S / dt}


def main():
    rows = []
    for arch in ARCH_IDS:
        r = bench_arch(arch)
        rows.append(r)
        print(f"{r['arch']},{r['us_per_call']:.0f},{r['tokens_per_s']:.0f}")
    return rows


if __name__ == "__main__":
    main()
