"""Per-arch train/decode step timing on reduced configs (CPU wall clock;
relative numbers). One row per assigned architecture, plus one row for the
Viterbi decoder itself — timed through the LIBRARY DEFAULTS (DecoderConfig:
packed survivors, radix-4, autotuned tiles), never a hand-rolled seed-era
knob set, so this row tracks whatever the blessed configuration is."""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.optim import adamw, constant
from repro.train import make_train_step


def _batch(cfg, B=2, S=32):
    b = {"tokens": jnp.ones((B, S), jnp.int32),
         "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.family == "encdec":
        b["frames"] = jnp.ones((B, S, cfg.d_model), jnp.float32)
    if cfg.vision_patches:
        b["vision_embeds"] = jnp.ones((B, cfg.vision_patches, cfg.d_model),
                                      jnp.float32)
    return b


def bench_arch(arch: str, reps: int = 5) -> dict:
    cfg = get_config(arch, reduced=True)
    m = build_model(cfg)
    opt = adamw(constant(1e-3))
    params = m.init(jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(m, opt))
    b = _batch(cfg)
    p, o, met = step(params, opt.init(params), b)      # compile
    jax.block_until_ready(met["loss"])
    t0 = time.perf_counter()
    for _ in range(reps):
        p, o, met = step(p, o, b)
    jax.block_until_ready(met["loss"])
    dt = (time.perf_counter() - t0) / reps
    B, S = b["tokens"].shape
    return {"arch": arch, "us_per_call": dt * 1e6,
            "tokens_per_s": B * S / dt}


def bench_decoder(reps: int = 3) -> dict:
    """Default-config Viterbi decode (kernel backend, DecoderConfig
    defaults — no explicit pack_survivors/radix/tile overrides)."""
    from repro.core import DecoderConfig, FrameSpec, make_decoder
    cfg = DecoderConfig(spec=FrameSpec(f=256, v1=20, v2=45, f0=32, v2s=45),
                        backend="kernel")
    dec = make_decoder(cfg)
    n = 16 * cfg.spec.f
    rng = np.random.default_rng(0)
    llr = jnp.asarray(rng.standard_normal((n, 2)).astype(np.float32))
    dec(llr, n).block_until_ready()                    # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        dec(llr, n).block_until_ready()
    dt = (time.perf_counter() - t0) / reps
    return {"arch": "viterbi_k7_default", "us_per_call": dt * 1e6,
            "tokens_per_s": n / dt}


def main():
    rows = [bench_decoder()]
    print(f"{rows[0]['arch']},{rows[0]['us_per_call']:.0f},"
          f"{rows[0]['tokens_per_s']:.0f}")
    for arch in ARCH_IDS:
        r = bench_arch(arch)
        rows.append(r)
        print(f"{r['arch']},{r['us_per_call']:.0f},{r['tokens_per_s']:.0f}")
    return rows


if __name__ == "__main__":
    main()
