"""Per-PR trajectory store for BENCH_kernels.json.

The kernel sweep used to overwrite the file each run; the benchmark-
regression gate (scripts/bench_gate.py) needs the history, so the file is
now a list of runs:

    {"schema": "kernel_sweep/v2", "runs": [run0, run1, ...]}

where each run holds the sweep rows plus the streaming and tile-plan
sections. A v1 file (single {"rows": ...} dict) is absorbed as the first
run so the PR-1 datapoint stays in the trajectory.
"""
from __future__ import annotations

import json
import os

SCHEMA = "kernel_sweep/v2"
DEFAULT_PATH = "BENCH_kernels.json"

__all__ = ["SCHEMA", "DEFAULT_PATH", "platform", "load_runs", "append_run",
           "best_mbps", "serve_mbps", "serve_under_faults_mbps",
           "block_mbps", "serve_load_p99"]


def platform() -> dict:
    """The JAX backend/device identity of THIS process — stamped on every
    run so the regression gate never compares, say, an interpret-CPU
    point against a compiled-TPU point (same code, ~100x apart). The
    same identity keys the measured-autotune DB (kernels/tunedb.py),
    which owns the definition; both stay lazy — loading the trajectory
    store must not initialize JAX."""
    from repro.kernels.tunedb import platform_id
    return platform_id()


def load_runs(path: str = DEFAULT_PATH) -> list[dict]:
    """Existing runs, oldest first ([] when the file is absent).

    Degenerate-but-honest stores parse to [] instead of raising or
    fabricating a junk run: an empty document (``{}``), a v2 envelope
    with no runs yet, or a bare JSON list (a hand-edited/partial store —
    its dict entries are kept). Only a STRUCTURALLY wrong file (v2
    envelope whose ``runs`` is not a list) raises — silently dropping
    real history would let a regression gate itself green."""
    if not os.path.exists(path):
        return []
    with open(path) as fh:
        data = json.load(fh)
    if isinstance(data, list):
        return [r for r in data if isinstance(r, dict)]
    if not isinstance(data, dict) or not data:
        return []
    if data.get("schema") == SCHEMA:
        runs = data.get("runs", [])
        if not isinstance(runs, list):
            raise ValueError(
                f"trajectory 'runs' is {type(runs).__name__}, expected a "
                f"list of runs")
        return runs
    if "rows" not in data:
        return []
    # v1: one run, {"schema": "kernel_sweep/v1", "full":..., "rows":[...]}
    return [{"full": data.get("full", False), "rows": data.get("rows", []),
             "schema_origin": data.get("schema", "v1")}]


def append_run(run: dict, path: str = DEFAULT_PATH) -> list[dict]:
    """Append ``run`` to the trajectory and rewrite ``path``. Every run
    is stamped with the producing process's ``platform`` (unless the
    caller already set one), so cross-platform points are separable
    forever after."""
    run.setdefault("platform", platform())
    runs = load_runs(path)
    runs.append(run)
    with open(path, "w") as fh:
        json.dump({"schema": SCHEMA, "runs": runs}, fh, indent=1,
                  sort_keys=True)
        fh.write("\n")
    return runs


def best_mbps(run: dict) -> float:
    """Best kernel-sweep throughput of a run (the regression-gate metric).

    Only rows with comparable workload metadata should be compared across
    runs; the gate checks ``full`` and ``n_bits`` before trusting this.
    """
    return max((r["mbps"] for r in run.get("rows", [])), default=0.0)


def serve_mbps(run: dict, variant: str = "server") -> float:
    """Aggregate serve throughput of a run's "serve" section (0.0 when the
    run predates the serve trajectory). ``variant`` picks the DecodeServer
    row ("server") or the N-independent-StreamDecoders baseline
    ("independent") — the gate compares server rows across runs with
    matching (sessions, n_bits) workloads."""
    return max((r["mbps"] for r in run.get("serve", [])
                if r.get("variant") == variant), default=0.0)


def serve_under_faults_mbps(run: dict) -> float:
    """Aggregate serve throughput of a run's "serve_faults" section — the
    DecodeServer workload with the seeded 1%-launch-failure injection
    (throughput.serve_faults_bench). 0.0 when the run predates the
    fault-tolerance trajectory; the gate compares rows across runs with
    matching (sessions, n_bits) like the clean serve section."""
    return max((r["mbps"] for r in run.get("serve_faults", [])
                if r.get("variant") == "server_faults"), default=0.0)


def serve_load_p99(run: dict, sessions: int) -> float:
    """End-to-end p99 window latency (ms) of a run's "serve_load" section
    (throughput.serve_load_sweep) at one offered-load level — the SLO
    curve datapoint the gate compares per level. 0.0 when the run
    predates the load sweep or never ran that level. NOTE the inverted
    gate semantics: lower is better, so the gate fails when the current
    p99 EXCEEDS (1 + tol) x the best (minimum) stored comparable p99."""
    return max((r["p99_ms"] for r in run.get("serve_load", [])
                if r.get("sessions") == sessions), default=0.0)


def block_mbps(run: dict, variant: str = "blocked") -> float:
    """Throughput of a run's "block" section (throughput.block_bench):
    ``variant`` picks the intra-frame block-parallel decode ("blocked")
    or the sequential single-scan plan of the same long-frame workload
    ("sequential"). 0.0 when the run predates the block trajectory."""
    return max((r["mbps"] for r in run.get("block", [])
                if r.get("variant") == variant), default=0.0)
