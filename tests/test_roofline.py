"""Calibration tests for the HLO cost model — these pin the semantics the
roofline relies on (per-device numbers; scan bodies multiplied by trip
count; collective byte attribution)."""
import subprocess
import sys
import os

import numpy as np
import pytest

from repro.launch.hlo_cost import module_cost, _shape_bytes
from repro.launch.roofline import collective_bytes

CALIB = r"""
import os
os.environ["XLA_FLAGS"]="--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
import sys
sys.path.insert(0, "src")
from repro.launch.hlo_cost import module_cost

mesh = jax.make_mesh((2,4), ("data","model"))
B, D = 64, 512
x = jax.ShapeDtypeStruct((B, D), jnp.float32)
w3 = jax.ShapeDtypeStruct((3, D, D), jnp.float32)
xs = NamedSharding(mesh, P("data", None))
ws = NamedSharding(mesh, P(None, None, "model"))

def f(x, w): return jnp.sum((x @ w[0])**2)
c = jax.jit(f, in_shardings=(xs, ws)).lower(x, w3).compile()
c1 = module_cost(c.as_text())
assert abs(c1.flops - 2*B*D*D/8) < 0.01*2*B*D*D/8, c1.flops
def _ca(c):
    a = c.cost_analysis() or {}
    if isinstance(a, list):  # jax<=0.4.x returns [dict]
        a = a[0] if a else {}
    return a

xla = float(_ca(c).get("flops", 0))
assert abs(xla - 2*B*D*D/8) < 0.01*2*B*D*D/8, xla  # per-device semantics

def g(x, w):
    def body(h, wi): return jnp.tanh(h @ wi), ()
    h, _ = jax.lax.scan(body, x, w)
    return jnp.sum(h)
c2 = jax.jit(g, in_shardings=(xs, ws)).lower(x, w3).compile()
cc = module_cost(c2.as_text())
want = 3*2*(B//2)*D*(D//4)
assert abs(cc.flops - want) < 0.01*want, (cc.flops, want)
# XLA counts the body ONCE (the reason hlo_cost exists):
xla2 = float(_ca(c2).get("flops", 0))
assert xla2 < 0.5 * want, (xla2, want)
# the all-gather inside the loop is counted x3
ag = cc.coll_raw["all-gather"]
assert abs(ag - 3*(B//2)*D*4) < 1, ag
print("CALIB_OK")
"""


def test_cost_model_calibration_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", CALIB], capture_output=True,
                       text=True, timeout=300, env=env,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert "CALIB_OK" in r.stdout, r.stdout + r.stderr


def test_shape_bytes():
    assert _shape_bytes("bf16[2,3,4]{2,1,0}") == 48
    assert _shape_bytes("(f32[8], s8[16])") == 48
    assert _shape_bytes("pred[10]") == 10


def test_collective_text_parser():
    hlo = """
ENTRY %main (p: f32[8]) -> f32[8] {
  %ag = f32[64,32]{1,0} all-gather(%x), replica_groups=[2,4]<=[8]
  %ar = bf16[128]{0} all-reduce-start(%y), channel_id=3
  %done = bf16[128]{0} all-reduce-done(%ar)
}
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 64 * 32 * 4
    assert out["all-reduce"] == 256
