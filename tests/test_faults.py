"""Fault-tolerance suite: sanitization, fault injection, retry/degrade,
quarantine, renormalization, and the hardened error surfaces.

The adversarial-input property tests pin the contract "sanitize OR raise,
never silent garbage": a poisoned buffer pushed through any decode entry
point either comes out exactly as if the caller had sanitized it first,
or raises a structured error — and a server that saw it keeps serving
its healthy tenants bit-identically.
"""
import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from conftest import noisy_llr
from repro.core import (DecoderConfig, FrameSpec, LLR_CLIP, STD_K7,
                        make_decoder, sanitize_llr, stream_decode)
from repro.core.stream import make_stream_decoder
from repro.serve import (Backpressure, DecodeServer, PlanCache,
                         PoisonedInput, ServeError, ServerFull,
                         SessionQuarantined)
from repro.testing import FaultInjector, FaultSpec, InjectedKernelError

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")

SPEC = FrameSpec(f=64, v1=16, v2=20, f0=16, v2s=20)


def _poison(llr, rng, mode, frac=0.2):
    """A copy of ``llr`` with a ``frac`` fraction of entries poisoned."""
    out = np.array(llr, np.float32)
    flat = out.reshape(-1)
    k = max(1, int(frac * flat.size))
    idx = rng.choice(flat.size, size=k, replace=False)
    val = {"nan": np.nan, "inf": np.inf, "huge": 1e30}[mode]
    flat[idx] = val
    if mode != "nan":
        flat[idx[1::2]] *= -1.0
    return out


# ---------------------------------------------------------------- sanitize
def test_sanitize_llr_policies(rng):
    llr = rng.standard_normal((64, 2)).astype(np.float32)
    # clean input: returned UNTOUCHED (the bit-identity fast path)
    out, n = sanitize_llr(llr)
    assert n == 0 and out is llr
    bad = llr.copy()
    bad[0, 0], bad[1, 1], bad[2, 0], bad[3, 1] = (np.nan, np.inf,
                                                  -np.inf, 2e6)
    out, n = sanitize_llr(bad)
    assert n == 4 and bad[3, 1] == 2e6          # input not mutated
    assert out[0, 0] == 0.0 and out[1, 1] == 0.0 and out[2, 0] == 0.0
    assert out[3, 1] == LLR_CLIP
    assert np.array_equal(out[4:], bad[4:])
    with pytest.raises(ValueError, match="4 non-finite"):
        sanitize_llr(bad, policy="raise")
    out, n = sanitize_llr(bad, policy="off")
    assert n == 0 and out is bad


@given(st.integers(0, 2**32 - 1), st.sampled_from(["nan", "inf", "huge"]))
def test_decode_poisoned_equals_decode_sanitized(seed, mode):
    """make_decoder's in-graph hardening: decoding a poisoned stream ==
    decoding its sanitized version (and returns finite, 0/1 bits)."""
    rng = np.random.default_rng(seed)
    n = 6 * SPEC.f
    llr = noisy_llr(rng.integers(0, 2, n), STD_K7, 3.0, rng)
    dec = make_decoder(DecoderConfig(spec=SPEC))
    bad = _poison(llr, rng, mode)
    clean, _ = sanitize_llr(bad)
    got = np.asarray(dec(bad, n))
    assert np.array_equal(got, np.asarray(dec(clean, n)))
    assert set(np.unique(got)) <= {0, 1}


@given(st.integers(0, 2**32 - 1), st.sampled_from(["nan", "inf", "huge"]))
def test_stream_push_poisoned_equals_sanitized_stream(seed, mode):
    """StreamDecoder.push sanitizes at the boundary: a poisoned chunk
    decodes exactly like the pre-sanitized stream, and the numeric
    counters record what was scrubbed."""
    rng = np.random.default_rng(seed)
    cfg = DecoderConfig(spec=SPEC)
    n = 12 * SPEC.f
    llr = noisy_llr(rng.integers(0, 2, n), STD_K7, 3.0, rng)
    bad = llr.copy()
    bad[: 4 * SPEC.f] = _poison(llr[: 4 * SPEC.f], rng, mode)
    clean, n_bad = sanitize_llr(bad)
    assert n_bad > 0
    dec = make_stream_decoder(cfg, chunk_frames=4)
    out = [dec.push(bad[i: i + 4 * SPEC.f])
           for i in range(0, n, 4 * SPEC.f)]
    assert dec.numeric_stats()["sanitized_values"] == n_bad
    out.append(dec.flush())                 # (flush resets the counters)
    got = np.concatenate(out)[:n]
    assert np.array_equal(got, stream_decode(cfg, clean, n, chunk_frames=4))


def test_stream_push_rejects_malformed_shapes(rng):
    dec = make_stream_decoder(DecoderConfig(spec=SPEC), chunk_frames=4)
    assert dec.push(np.zeros((0, 2), np.float32)).size == 0   # empty: OK
    with pytest.raises(ValueError, match="flat or"):
        dec.push(np.zeros((2, 3, 2), np.float32))             # 3-D
    with pytest.raises(ValueError):
        dec.push(np.zeros((5, 3), np.float32))                # beta != 2
    # the decoder survives rejected pushes: a clean stream still decodes
    n = 6 * SPEC.f
    llr = noisy_llr(rng.integers(0, 2, n), STD_K7, 4.0, rng)
    got = np.concatenate([dec.push(llr), dec.flush()])[:n]
    assert np.array_equal(got, stream_decode(DecoderConfig(spec=SPEC),
                                             llr, n, chunk_frames=4))


# ------------------------------------------------------- error hierarchy
def test_serve_error_hierarchy_and_retry_hint():
    for exc in (ServerFull, Backpressure, PoisonedInput,
                SessionQuarantined):
        assert issubclass(exc, ServeError)
    assert issubclass(ServeError, RuntimeError)     # old except-clauses
    srv = DecodeServer(slots=1, max_sessions=1, queue_depth=2)
    sid = srv.open_session(DecoderConfig(spec=SPEC), chunk_frames=2)
    with pytest.raises(ServerFull, match="max_sessions") as ei:
        srv.open_session(DecoderConfig(spec=SPEC))
    assert ei.value.retry_after_steps is None
    with pytest.raises(Backpressure, match="step") as ei:
        srv.push(sid, np.zeros((20 * SPEC.f, 2), np.float32))
    hint = ei.value.retry_after_steps
    assert isinstance(hint, int) and hint >= 1
    # the hint is honest: that many steps really do clear the condition
    srv.push(sid, np.zeros((4 * SPEC.f, 2), np.float32))
    with pytest.raises(Backpressure, match="split") as ei:
        srv.push(sid, np.zeros((4 * SPEC.f, 2), np.float32))
    for _ in range(ei.value.retry_after_steps):
        srv.step()
    srv.push(sid, np.zeros((4 * SPEC.f, 2), np.float32))


# ------------------------------------------------------- server hardening
def test_server_quarantines_poison_keeps_healthy_tenant_bit_exact(rng):
    cfg = DecoderConfig(spec=SPEC)
    n = 12 * SPEC.f
    healthy = noisy_llr(rng.integers(0, 2, n), STD_K7, 3.0, rng)
    srv = DecodeServer(slots=2, cache=PlanCache(), quarantine_after=2)
    bad_sid = srv.open_session(cfg, chunk_frames=4)
    ok_sid = srv.open_session(cfg, chunk_frames=4)
    per = 4 * SPEC.f
    raised = []
    for r in range(3):
        chunk = np.full((per, 2), np.nan, np.float32)
        try:
            srv.push(bad_sid, chunk)
        except SessionQuarantined as e:
            raised.append(e)
        srv.push(ok_sid, healthy[r * per:(r + 1) * per])
        while srv.step():
            pass
    assert len(raised) == 1 and raised[0].sid == bad_sid
    assert raised[0].strikes == 2 and raised[0].retry_after_steps is None
    with pytest.raises(SessionQuarantined):
        srv.poll(bad_sid)
    snap = srv.metrics_snapshot()
    assert snap["quarantined_sessions"] == 1
    assert snap["totals"]["quarantined"] == 1
    assert snap["totals"]["poisoned_pushes"] >= 2
    assert snap["totals"]["sanitized_values"] >= 2 * per * 2
    assert snap["totals"]["health"] == "impaired"
    st_bad = srv.session_state(bad_sid)
    assert st_bad["quarantined"] and st_bad["strikes"] == 2
    # the bucket-mate never noticed
    got = np.concatenate([srv.poll(ok_sid), srv.close_session(ok_sid)])[:n]
    assert np.array_equal(got, stream_decode(cfg, healthy, n,
                                             chunk_frames=4))
    bits = srv.close_session(bad_sid)           # teardown always works
    assert bits.dtype == np.int32 and srv.num_sessions == 0


def test_server_raise_policy_rejects_without_absorbing(rng):
    cfg = DecoderConfig(spec=SPEC)
    srv = DecodeServer(cache=PlanCache(), sanitize="raise")
    sid = srv.open_session(cfg, chunk_frames=4)
    n = 6 * SPEC.f
    llr = noisy_llr(rng.integers(0, 2, n), STD_K7, 3.0, rng)
    bad = llr.copy()
    bad[0, 0] = np.inf
    with pytest.raises(PoisonedInput, match="non-finite"):
        srv.push(sid, bad)
    # nothing was absorbed: the clean retry decodes the whole stream
    srv.push(sid, llr)
    got = srv.close_session(sid)[:n]
    assert np.array_equal(got, stream_decode(cfg, llr, n, chunk_frames=4))


def _run_faulted_server(rng, faults, n_chunks=3, **server_kw):
    """One session through a faulted server; returns (got, want, srv)."""
    cfg = DecoderConfig(spec=SPEC)
    n = n_chunks * 4 * SPEC.f
    llr = noisy_llr(rng.integers(0, 2, n), STD_K7, 3.0, rng)
    srv = DecodeServer(slots=2, cache=PlanCache(), faults=faults,
                       backoff_s=0.0, **server_kw)
    sid = srv.open_session(cfg, chunk_frames=4)
    per = 4 * SPEC.f
    for r in range(n_chunks):
        srv.push(sid, llr[r * per:(r + 1) * per])
        while srv.step():
            pass
    got = np.concatenate([srv.poll(sid), srv.close_session(sid)])[:n]
    return got, stream_decode(cfg, llr, n, chunk_frames=4), srv


def test_server_retries_then_degrades_and_stays_correct(rng):
    """Every launch attempt fails -> retries exhaust -> the reference
    fallback serves the batch; the session's bits are still exactly the
    solo stream_decode result."""
    faults = FaultInjector(FaultSpec("launch_error", every=1), seed=0)
    got, want, srv = _run_faulted_server(rng, faults, max_retries=1)
    assert np.array_equal(got, want)
    tot = srv.metrics.totals()
    assert tot["degraded"] >= 1 and tot["health"] == "degraded"
    assert tot["launch_errors"] == 2 * tot["degraded"]   # 2 attempts each
    assert tot["retries"] == tot["degraded"]
    assert (srv.metrics_snapshot()["faults"]["injected"]["launch_error"]
            == tot["launch_errors"])


def test_server_deadline_timeout_degrades_and_stays_correct(rng):
    """A launch stuck past launch_timeout_s is treated as failed: with
    max_retries=0 it degrades immediately, bits stay exact."""
    faults = FaultInjector(
        FaultSpec("launch_slow", every=1, delay_s=0.05), seed=0)
    got, want, srv = _run_faulted_server(rng, faults, max_retries=0,
                                         launch_timeout_s=0.01)
    assert np.array_equal(got, want)
    tot = srv.metrics.totals()
    assert tot["timeouts"] >= 1 and tot["degraded"] >= 1
    assert tot["launch_errors"] == 0            # slow, not broken


def test_server_survives_forced_plan_cache_misses(rng):
    """Injected cache evictions force the cold path on a live server:
    rebuild + retrace, same bits."""
    faults = FaultInjector(FaultSpec("plan_cache_miss", every=2), seed=0)
    got, want, srv = _run_faulted_server(rng, faults)
    assert np.array_equal(got, want)
    tot = srv.metrics.totals()
    assert tot["cache_refreshes"] >= 1
    assert tot["degraded"] == 0 and tot["launch_errors"] == 0
    assert srv.cache.stats()["misses"] >= 1 + tot["cache_refreshes"]


def test_stream_decoder_fault_propagates_no_retry(rng):
    """The single-stream front-end has no retry layer: an injected
    launch fault reaches the caller (the server is the resilient tier)."""
    faults = FaultInjector(FaultSpec("launch_error", every=1), seed=0)
    dec = make_stream_decoder(DecoderConfig(spec=SPEC), chunk_frames=4,
                              faults=faults)
    llr = noisy_llr(rng.integers(0, 2, 8 * SPEC.f), STD_K7, 4.0, rng)
    with pytest.raises(InjectedKernelError):
        dec.push(llr)


# ------------------------------------------------------- renormalization
def test_renorm_every_bit_identical_on_clean_long_stream(rng):
    """Periodic (and disabled) path-metric renormalization is bit-
    identical to the per-stage default on a clean long stream — max-
    normalization only shifts all metrics by a constant."""
    cfg = DecoderConfig(spec=SPEC)                      # renorm_every=1
    n = 96 * SPEC.f
    llr = noisy_llr(rng.integers(0, 2, n), STD_K7, 2.0, rng)
    want = stream_decode(cfg, llr, n, chunk_frames=16)
    for every in (0, 7):
        got = stream_decode(dataclasses.replace(cfg, renorm_every=every),
                            llr, n, chunk_frames=16)
        assert np.array_equal(got, want), f"renorm_every={every}"


def test_renorm_every_validation():
    with pytest.raises(ValueError, match="renorm_every"):
        DecoderConfig(spec=SPEC, renorm_every=-1)
    with pytest.raises(ValueError, match="renormalize every stage"):
        DecoderConfig(spec=SPEC, backend="kernel", renorm_every=0)


# ------------------------------------------------------- kernel ops entry
def test_kernel_ops_entry_validation():
    from repro.kernels import ops
    frames = jnp.zeros((4, SPEC.frame_len, 2), jnp.float32)
    kw = dict(frames_per_tile=4, interpret=True)
    with pytest.raises(ValueError, match="2-D"):
        ops.viterbi_decode_frames(frames[0], STD_K7, SPEC, **kw)
    with pytest.raises(ValueError, match="frame_len"):
        ops.viterbi_decode_frames(frames[:, :-1], STD_K7, SPEC, **kw)
    with pytest.raises(ValueError, match="beta"):
        ops.viterbi_decode_frames(frames[..., :1], STD_K7, SPEC, **kw)
    with pytest.raises(ValueError, match="floating"):
        ops.viterbi_decode_frames(frames.astype(jnp.int32), STD_K7, SPEC,
                                  **kw)


# ------------------------------------------------------- bench gate CLI
def test_bench_gate_fails_fast_with_clear_error_on_corrupt_file(tmp_path):
    bad = tmp_path / "BENCH_corrupt.json"
    bad.write_text("{not json")
    env = dict(os.environ, BENCH_PATH=str(bad))
    root = os.path.join(os.path.dirname(__file__), "..")
    proc = subprocess.run(
        [sys.executable, os.path.join(root, "scripts", "bench_gate.py")],
        env=env, capture_output=True, text=True, timeout=120, cwd=root)
    assert proc.returncode == 2, proc.stdout + proc.stderr
    assert "bench gate: ERROR" in proc.stdout
    assert "cannot be read" in proc.stdout
    assert "Traceback" not in proc.stdout + proc.stderr
    # and a structurally-valid file with an unexpected payload also gets
    # the clear message, not an IndexError downstream
    bad.write_text(json.dumps({"schema": "kernel_sweep/v2", "runs": 17}))
    proc = subprocess.run(
        [sys.executable, os.path.join(root, "scripts", "bench_gate.py")],
        env=env, capture_output=True, text=True, timeout=120, cwd=root)
    assert proc.returncode == 2, proc.stdout + proc.stderr
    assert "bench gate: ERROR" in proc.stdout
