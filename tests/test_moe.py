"""MoE dispatch oracle: grouped one-hot einsum dispatch == per-token loop."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, MoESpec
from repro.models.moe import init_moe, moe_ff


def _cfg(E=4, k=2, cap=99.0):
    return ModelConfig(
        name="t", family="moe", num_layers=1, d_model=16, num_heads=2,
        num_kv_heads=2, d_ff=32, vocab=64, dtype="float32",
        moe=MoESpec(num_experts=E, top_k=k, d_ff_expert=32, group_size=8,
                    capacity_per_choice=cap))


def _oracle(p, x, cfg):
    """Per-token python loop, no capacity limits, renormalized top-k."""
    m = cfg.moe
    B, S, d = x.shape
    out = np.zeros((B, S, d), np.float32)
    probs = jax.nn.softmax(np.asarray(x, np.float32) @ np.asarray(p["router"]), -1)
    wg, wu, wd = (np.asarray(p[k], np.float32) for k in ("ewg", "ewu", "ewd"))
    for b in range(B):
        for s in range(S):
            pr = probs[b, s].copy()
            idx = np.argsort(-pr)[: m.top_k]
            wsum = pr[idx].sum()
            for e in idx:
                h = np.asarray(jax.nn.silu(x[b, s] @ wg[e])) * (x[b, s] @ wu[e])
                out[b, s] += (pr[e] / wsum) * (h @ wd[e])
    return out


def test_moe_matches_per_token_oracle():
    cfg = _cfg()
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16), jnp.float32)
    y, aux = moe_ff(p, x, cfg)
    want = _oracle(p, np.asarray(x), cfg)
    np.testing.assert_allclose(np.asarray(y), want, rtol=2e-4, atol=2e-5)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    """With tight capacity some tokens lose experts; output stays finite and
    the kept-weight renormalization holds."""
    cfg = _cfg(cap=0.5)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16), jnp.float32)
    y, _ = moe_ff(p, x, cfg)
    assert np.all(np.isfinite(np.asarray(y)))
    y_full, _ = moe_ff(p, x, _cfg(cap=99.0))
    assert not np.allclose(np.asarray(y), np.asarray(y_full))


def test_moe_shared_expert():
    cfg = _cfg()
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, shared_expert=True))
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 16), jnp.float32)
    y, _ = moe_ff(p, x, cfg)
    sp = p["shared"]
    shared = (jax.nn.silu(x @ sp["wg"]) * (x @ sp["wu"])) @ sp["wd"]
    routed = _oracle(p, np.asarray(x), cfg)
    np.testing.assert_allclose(np.asarray(y), routed + np.asarray(shared),
                               rtol=2e-4, atol=2e-5)
