"""Unit tests for kernels/autotune.py — the VMEM-budget tile planner."""
import pytest

from repro.core import FrameSpec, STD_K7
from repro.core.trellis import make_trellis
from repro.kernels.autotune import (CANDIDATE_TILES, DEFAULT_VMEM_BUDGET,
                                    mosaic_padded_bytes, plan_decode,
                                    plan_tiles, split_vmem_bytes,
                                    unified_vmem_bytes)
from repro.kernels.packing import Layout

SPEC = FrameSpec(f=256, v1=20, v2=45, f0=32, v2s=45)


def test_footprint_matches_kernel_scratch():
    """The model's sel term is the kernel's (L, FT, S) int32 scratch, and
    packing shrinks exactly that term 32x for S=64 (the acceptance spec:
    (L, FT, S) -> (L, FT, S // 32))."""
    L, FT, S = SPEC.frame_len, 8, STD_K7.num_states
    _, plain = unified_vmem_bytes(STD_K7, SPEC, FT)
    _, packed = unified_vmem_bytes(STD_K7, SPEC, FT, pack_survivors=True)
    d_plain, d_packed = dict(plain), dict(packed)
    assert d_plain["sel_survivors"] == L * FT * S * 4
    assert d_packed["sel_survivors"] == L * FT * (S // 32) * 4
    assert d_plain["sel_survivors"] == 32 * d_packed["sel_survivors"]
    # everything else is knob-independent
    for k in d_plain:
        if k != "sel_survivors":
            assert d_plain[k] == d_packed[k]


def test_footprint_scales_linearly_in_ft():
    t8, _ = unified_vmem_bytes(STD_K7, SPEC, 8)
    t32, _ = unified_vmem_bytes(STD_K7, SPEC, 32)
    assert t32 == 4 * t8


def test_packed_plan_is_deeper():
    plain = plan_tiles(STD_K7, SPEC)
    packed = plan_tiles(STD_K7, SPEC, pack_survivors=True)
    assert plain.frames_per_tile >= 8
    assert packed.frames_per_tile >= 32          # the acceptance target
    assert packed.frames_per_tile > plain.frames_per_tile
    assert packed.vmem_bytes <= packed.budget == DEFAULT_VMEM_BUDGET


def test_plan_respects_budget_and_floor():
    # a tiny budget still yields the smallest candidate (kernel must run)
    p = plan_tiles(STD_K7, SPEC, vmem_budget=1)
    assert p.frames_per_tile == CANDIDATE_TILES[0]
    # a huge budget tops out at the largest candidate
    p = plan_tiles(STD_K7, SPEC, pack_survivors=True, vmem_budget=1 << 30)
    assert p.frames_per_tile == CANDIDATE_TILES[-1]
    assert 0 < p.utilization() < 1


def test_plan_caps_at_stream_length():
    p = plan_tiles(STD_K7, SPEC, pack_survivors=True, max_frames=5)
    assert p.frames_per_tile == 8                # one tile covers 5 frames


def test_plan_scales_with_state_count():
    """K=9 (S=256) frames are 4x heavier: the plan must shrink, not OOM."""
    k9 = make_trellis(9, (0o753, 0o561))
    p7 = plan_tiles(STD_K7, SPEC, pack_survivors=True)
    p9 = plan_tiles(k9, SPEC, pack_survivors=True)
    assert p9.frames_per_tile < p7.frames_per_tile
    assert p9.vmem_bytes <= p9.budget


def test_mosaic_padding_model():
    """The padded model is the (8,128)-tile arithmetic: trailing dim to
    128 lanes, second-to-last to 32/itemsize sublanes."""
    assert mosaic_padded_bytes((340, 32, 2), 4) == 340 * 32 * 128 * 4
    assert mosaic_padded_bytes((680, 128), 4) == 680 * 128 * 4  # no padding
    assert mosaic_padded_bytes((2, 128), 4) == 8 * 128 * 4      # sublane pad
    assert mosaic_padded_bytes((2, 128), 2) == 16 * 128 * 2     # bf16 tile
    assert mosaic_padded_bytes((2, 128), 1) == 32 * 128 * 1     # int8 tile
    assert mosaic_padded_bytes((64,), 4) == 8 * 128 * 4   # 1D: one full tile


def test_lane_packing_evaporates_under_mosaic():
    """The ROADMAP open item, as arithmetic: under padded accounting the
    lane layout's packed sel term is as large as the unpacked one (both
    lane-pad to 128), while the sublane layout's flat scratch keeps the
    full 32x."""
    _, lane_p = unified_vmem_bytes(STD_K7, SPEC, 32, pack_survivors=True,
                                   mosaic=True)
    _, lane_u = unified_vmem_bytes(STD_K7, SPEC, 32, mosaic=True)
    assert dict(lane_p)["sel_survivors"] == dict(lane_u)["sel_survivors"]
    _, sub_p = unified_vmem_bytes(STD_K7, SPEC, 128, pack_survivors=True,
                                  layout=Layout.SUBLANE)
    L, S = SPEC.frame_len, STD_K7.num_states
    assert dict(sub_p)["sel_survivors"] == \
        mosaic_padded_bytes((L * (S // 32), 128), 4)
    # per frame, the flat sublane scratch is >32x below the lane layout's
    # padded term (63x here: 128-lane padding of W=2 words)
    assert 32 * dict(sub_p)["sel_survivors"] / 128 \
        < dict(lane_p)["sel_survivors"] / 32


def test_sublane_plan_doubles_frames_at_equal_budget():
    """Acceptance criterion: under hardware-honest (mosaic) accounting at
    the SAME 2 MiB budget, the sublane-major packed plan fits >= 2x the
    frames per tile of the lane layout (and >= 2x PR 1's best recorded
    auto plan, ft=32)."""
    lane = plan_tiles(STD_K7, SPEC, pack_survivors=True, radix=4,
                      mosaic=True)
    sub = plan_tiles(STD_K7, SPEC, pack_survivors=True, radix=4,
                     layout=Layout.SUBLANE)
    assert sub.mosaic and sub.vmem_bytes <= sub.budget
    assert sub.frames_per_tile >= 2 * lane.frames_per_tile
    assert sub.frames_per_tile >= 2 * 32            # PR-1's BENCH best plan


def test_split_model_is_smaller_and_plans_deeper():
    """plan_tiles(unified=False) budgets the forward kernel's footprint
    (no survivor scratch / traceback arrays), so at a pinched budget the
    split plan fits at least as many frames per tile."""
    for ft in (8, 32):
        u, _ = unified_vmem_bytes(STD_K7, SPEC, ft, pack_survivors=True)
        s, bd = split_vmem_bytes(STD_K7, SPEC, ft, pack_survivors=True)
        assert s < u
        assert {n for n, _ in bd} == {"llr_block", "bm_compressed",
                                      "sel_stream", "amax_stream"}
    budget = 300 * 1024     # fits split ft=32 (281 KiB), unified only ft=16
    pu = plan_tiles(STD_K7, SPEC, pack_survivors=True, vmem_budget=budget)
    ps = plan_tiles(STD_K7, SPEC, pack_survivors=True, vmem_budget=budget,
                    unified=False)
    assert ps.kernel == "split" and pu.kernel == "unified"
    assert ps.frames_per_tile > pu.frames_per_tile


def test_bf16_halves_bm_term():
    _, f32 = unified_vmem_bytes(STD_K7, SPEC, 32, pack_survivors=True)
    _, bf16 = unified_vmem_bytes(STD_K7, SPEC, 32, pack_survivors=True,
                                 bm_dtype="bfloat16")
    assert dict(bf16)["bm_compressed"] == dict(f32)["bm_compressed"] // 2
    with pytest.raises(ValueError, match="bm_dtype"):
        unified_vmem_bytes(STD_K7, SPEC, 32, bm_dtype="float16")


def test_plan_decode_full_plan():
    """plan_decode returns everything the front-end executes: auto layout
    resolves to sublane for this geometry, kernel kwargs splat into ops,
    and the chunk is a multiple of tiles x devices."""
    p = plan_decode(STD_K7, SPEC, num_devices=4)
    assert p.tile.layout is Layout.SUBLANE
    assert p.unified and p.pack_survivors and p.radix == 4
    assert p.chunk_frames == 2 * p.frames_per_tile * 4
    kw = p.kernel_kwargs()
    assert kw["layout"] == "sublane" and kw["unified"] is True
    assert kw["frames_per_tile"] == p.frames_per_tile
    # split planning flows through too
    ps = plan_decode(STD_K7, SPEC, unified=False)
    assert not ps.unified and ps.tile.kernel == "split"


def test_candidates_lift_the_256_cap():
    """The ROADMAP open item: candidates now grow from the budget up to
    the frame count. At 8 MiB the packed sublane plan exceeds the old 256
    cap; max_frames still picks the smallest covering candidate; the
    MAX_FRAMES_PER_TILE backstop bounds an unlimited budget."""
    from repro.kernels.autotune import MAX_FRAMES_PER_TILE
    assert CANDIDATE_TILES[-1] == MAX_FRAMES_PER_TILE > 256
    p = plan_tiles(STD_K7, SPEC, pack_survivors=True, radix=4,
                   layout=Layout.SUBLANE, vmem_budget=8 * 1024 * 1024)
    assert p.frames_per_tile == 512 > 256
    assert p.vmem_bytes <= p.budget
    p2 = plan_tiles(STD_K7, SPEC, pack_survivors=True, radix=4,
                    layout=Layout.SUBLANE, vmem_budget=1 << 30,
                    max_frames=300)
    assert p2.frames_per_tile == 512          # smallest candidate >= 300


def test_kernel_runs_beyond_256_frames_per_tile():
    """A >256 sublane tile actually decodes, bit-exact vs the reference
    (the plan space beyond the old cap is real, not just arithmetic)."""
    import numpy as np
    import jax.numpy as jnp
    from repro.core.framed import FrameSpec, frame_llr
    from repro.kernels import ops, ref
    spec = FrameSpec(f=16, v1=8, v2=8)        # small L: 330 frames is cheap
    rng = np.random.default_rng(0)
    llr = jnp.asarray(rng.standard_normal((330 * 16, 2)).astype(np.float32))
    frames = frame_llr(llr, spec)
    want = np.asarray(ref.unified_decode_frames_ref(frames, STD_K7, spec))
    got = np.asarray(ops.viterbi_decode_frames(
        frames, STD_K7, spec, frames_per_tile=512, pack_survivors=True,
        radix=4, layout="sublane", interpret=True))
    assert np.array_equal(got, want)


def test_plan_cache_key_and_pinned_tile():
    """cache_key() is the serve layer's bucket identity: stable across
    equal plans, sensitive to every knob; frames_per_tile= pins the tile
    the session actually launches with (no autotuning surprise in the
    padding accounting)."""
    a = plan_decode(STD_K7, SPEC)
    b = plan_decode(STD_K7, SPEC)
    assert a.cache_key() == b.cache_key()
    assert a.fingerprint() == b.fingerprint()
    c = plan_decode(STD_K7, SPEC, radix=2)
    assert a.cache_key() != c.cache_key()
    d = plan_decode(STD_K7, SPEC, chunk_frames=7)
    assert a.cache_key() != d.cache_key()
    p = plan_decode(STD_K7, SPEC, layout="lane", frames_per_tile=8)
    assert p.frames_per_tile == 8 and p.tile.layout is Layout.LANE
    assert p.chunk_frames == 2 * 8            # chunk follows the pinned tile


def test_geometry_validation_errors():
    """plan_tiles rejects broken subframe geometry with actionable errors
    (via FrameSpec.validate — one source of truth for the invariants)."""
    with pytest.raises(ValueError, match="multiple of f0"):
        plan_tiles(STD_K7, FrameSpec(f=256, v1=20, v2=45, f0=48, v2s=45))
    with pytest.raises(ValueError, match="exceeds v2"):
        plan_tiles(STD_K7, FrameSpec(f=256, v1=20, v2=20, f0=32, v2s=45))
    plan_tiles(STD_K7, SPEC)                     # sane spec passes


def test_plan_identity_differs_for_every_knob():
    """Property: cache_key()/fingerprint() are injective over the knobs —
    any single-knob change (including the block decomposition) yields a
    distinct identity, so the plan cache and serve buckets can never
    alias two plans that compile or decode differently."""
    import dataclasses
    base = plan_decode(STD_K7, SPEC, layout="sublane")
    variants = [
        ("frames_per_tile",
         dataclasses.replace(base, tile=dataclasses.replace(
             base.tile, frames_per_tile=base.tile.frames_per_tile * 2))),
        ("kernel", dataclasses.replace(base, tile=dataclasses.replace(
            base.tile, kernel="split"))),
        ("layout", dataclasses.replace(base, tile=dataclasses.replace(
            base.tile, layout=Layout.LANE))),
        ("bm_dtype", dataclasses.replace(base, tile=dataclasses.replace(
            base.tile, bm_dtype="bfloat16"))),
        ("pack_survivors", dataclasses.replace(base, pack_survivors=False)),
        ("radix", dataclasses.replace(base, radix=2)),
        ("chunk_frames",
         dataclasses.replace(base, chunk_frames=base.chunk_frames + 1)),
        ("num_devices", dataclasses.replace(base, num_devices=2)),
        ("block_frames", dataclasses.replace(base, block_frames=4,
                                             overlap=16)),
        ("overlap", dataclasses.replace(base, block_frames=4, overlap=20)),
    ]
    plans = [("base", base)] + variants
    keys = {}
    for name, plan in plans:
        key, fp = plan.cache_key(), plan.fingerprint()
        for other, (okey, ofp) in keys.items():
            assert key != okey, f"{name} aliases {other} in cache_key()"
            assert fp != ofp, f"{name} aliases {other} in fingerprint()"
        keys[name] = (key, fp)
    # footprint BOOKKEEPING is deliberately NOT identity: two plans that
    # picked the same knobs compile to the same kernel
    relabeled = dataclasses.replace(base, tile=dataclasses.replace(
        base.tile, vmem_bytes=base.tile.vmem_bytes + 1))
    assert relabeled.cache_key() == base.cache_key()
    assert relabeled.fingerprint() == base.fingerprint()


def test_fingerprint_stable_across_processes():
    """fingerprint() must be reproducible in a FRESH interpreter: the
    serve checkpoint stores it and a restored server recomputes it, so a
    hash seeded per-process (e.g. str hashing) would break every restore.
    Also pins the blocked-plan identity so a knob silently dropped from
    cache_key() fails loudly."""
    import subprocess
    import sys
    prog = (
        "from repro.core import FrameSpec, STD_K7\n"
        "from repro.kernels.autotune import plan_decode\n"
        "spec = FrameSpec(f=256, v1=20, v2=45, f0=32, v2s=45)\n"
        "p = plan_decode(STD_K7, spec, layout='sublane',\n"
        "                block_frames=4, overlap=45)\n"
        "print(p.fingerprint())\n")
    here = plan_decode(STD_K7, SPEC, layout="sublane",
                       block_frames=4, overlap=45)
    assert here.block_frames == 4 and here.overlap == 45
    out = subprocess.run([sys.executable, "-c", prog], check=True,
                         capture_output=True, text=True, env=None)
    assert out.stdout.strip() == here.fingerprint()
