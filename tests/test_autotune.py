"""Unit tests for kernels/autotune.py — the VMEM-budget tile planner."""
import pytest

from repro.core import FrameSpec, STD_K7
from repro.core.trellis import make_trellis
from repro.kernels.autotune import (CANDIDATE_TILES, DEFAULT_VMEM_BUDGET,
                                    plan_tiles, unified_vmem_bytes)

SPEC = FrameSpec(f=256, v1=20, v2=45, f0=32, v2s=45)


def test_footprint_matches_kernel_scratch():
    """The model's sel term is the kernel's (L, FT, S) int32 scratch, and
    packing shrinks exactly that term 32x for S=64 (the acceptance spec:
    (L, FT, S) -> (L, FT, S // 32))."""
    L, FT, S = SPEC.frame_len, 8, STD_K7.num_states
    _, plain = unified_vmem_bytes(STD_K7, SPEC, FT)
    _, packed = unified_vmem_bytes(STD_K7, SPEC, FT, pack_survivors=True)
    d_plain, d_packed = dict(plain), dict(packed)
    assert d_plain["sel_survivors"] == L * FT * S * 4
    assert d_packed["sel_survivors"] == L * FT * (S // 32) * 4
    assert d_plain["sel_survivors"] == 32 * d_packed["sel_survivors"]
    # everything else is knob-independent
    for k in d_plain:
        if k != "sel_survivors":
            assert d_plain[k] == d_packed[k]


def test_footprint_scales_linearly_in_ft():
    t8, _ = unified_vmem_bytes(STD_K7, SPEC, 8)
    t32, _ = unified_vmem_bytes(STD_K7, SPEC, 32)
    assert t32 == 4 * t8


def test_packed_plan_is_deeper():
    plain = plan_tiles(STD_K7, SPEC)
    packed = plan_tiles(STD_K7, SPEC, pack_survivors=True)
    assert plain.frames_per_tile >= 8
    assert packed.frames_per_tile >= 32          # the acceptance target
    assert packed.frames_per_tile > plain.frames_per_tile
    assert packed.vmem_bytes <= packed.budget == DEFAULT_VMEM_BUDGET


def test_plan_respects_budget_and_floor():
    # a tiny budget still yields the smallest candidate (kernel must run)
    p = plan_tiles(STD_K7, SPEC, vmem_budget=1)
    assert p.frames_per_tile == CANDIDATE_TILES[0]
    # a huge budget tops out at the largest candidate
    p = plan_tiles(STD_K7, SPEC, pack_survivors=True, vmem_budget=1 << 30)
    assert p.frames_per_tile == CANDIDATE_TILES[-1]
    assert 0 < p.utilization() < 1


def test_plan_caps_at_stream_length():
    p = plan_tiles(STD_K7, SPEC, pack_survivors=True, max_frames=5)
    assert p.frames_per_tile == 8                # one tile covers 5 frames


def test_plan_scales_with_state_count():
    """K=9 (S=256) frames are 4x heavier: the plan must shrink, not OOM."""
    k9 = make_trellis(9, (0o753, 0o561))
    p7 = plan_tiles(STD_K7, SPEC, pack_survivors=True)
    p9 = plan_tiles(k9, SPEC, pack_survivors=True)
    assert p9.frames_per_tile < p7.frames_per_tile
    assert p9.vmem_bytes <= p9.budget


def test_geometry_validation_errors():
    """plan_tiles rejects broken subframe geometry with actionable errors
    (via FrameSpec.validate — one source of truth for the invariants)."""
    with pytest.raises(ValueError, match="multiple of f0"):
        plan_tiles(STD_K7, FrameSpec(f=256, v1=20, v2=45, f0=48, v2s=45))
    with pytest.raises(ValueError, match="exceeds v2"):
        plan_tiles(STD_K7, FrameSpec(f=256, v1=20, v2=20, f0=32, v2s=45))
    plan_tiles(STD_K7, SPEC)                     # sane spec passes
