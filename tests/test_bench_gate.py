"""Tests for scripts/bench_gate.py platform separation and the
serve-load trajectory accessors.

The gate's contract since compiled-mode benching: one BENCH_kernels.json
may hold interpret-CPU runs AND compiled-GPU/TPU runs of the same code
(orders of magnitude apart), and every comparison must stay inside one
platform. Pre-stamp legacy runs (no "platform" key) were all produced by
interpret-CPU runs and must gate as such — and never against a stamped
compiled run.
"""
import importlib.util
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _bench_gate():
    spec = importlib.util.spec_from_file_location(
        "bench_gate", os.path.join(ROOT, "scripts", "bench_gate.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


bench_gate = _bench_gate()

CPU = {"backend": "cpu", "device_kind": "cpu"}
GPU = {"backend": "gpu", "device_kind": "NVIDIA A100"}

#: Minimal gateable run: quick workload, one kernel row at N_BITS.
N_BITS = 8192


def _run(platform=None, n_bits=N_BITS, full=False, mbps=1.0):
    run = {"full": full, "rows": [{"n_bits": n_bits, "mbps": mbps}]}
    if platform is not None:
        run["platform"] = dict(platform, jax_version="0.0.0")
    return run


def test_pre_stamp_runs_assume_legacy_cpu():
    legacy = _run(platform=None)
    assert bench_gate._run_platform(legacy) == CPU
    # ... so they ARE comparable to a cpu run ...
    assert bench_gate.comparable_runs([legacy], CPU, N_BITS) == [legacy]
    # ... and NEVER to a stamped compiled run
    assert bench_gate.comparable_runs([legacy], GPU, N_BITS) == []


def test_two_platform_trajectory_gates_independently():
    cpu_runs = [_run(CPU, mbps=1.0), _run(CPU, mbps=1.1)]
    gpu_runs = [_run(GPU, mbps=900.0), _run(GPU, mbps=950.0)]
    prior = [cpu_runs[0], gpu_runs[0], cpu_runs[1], gpu_runs[1]]
    assert bench_gate.comparable_runs(prior, CPU, N_BITS) == cpu_runs
    assert bench_gate.comparable_runs(prior, GPU, N_BITS) == gpu_runs


def test_device_kind_alone_separates():
    """Same backend, different device kind (e.g. two GPU generations)
    must not be compared — compiled perf is device-specific."""
    a100 = _run(GPU)
    h100 = _run({"backend": "gpu", "device_kind": "NVIDIA H100"})
    got = bench_gate.comparable_runs([a100, h100], GPU, N_BITS)
    assert got == [a100]


def test_workload_filter_still_applies():
    wrong_bits = _run(CPU, n_bits=N_BITS * 2)
    full = _run(CPU, full=True)
    ok = _run(CPU)
    got = bench_gate.comparable_runs([wrong_bits, full, ok], CPU, N_BITS)
    assert got == [ok]


def test_current_platform_matches_tunedb_identity():
    """trajectory.platform() and the tune-DB platform_id() must be the
    same identity — one measurement key, one run stamp."""
    sys.path.insert(0, os.path.join(ROOT, "src"))
    sys.path.insert(0, ROOT)
    from benchmarks.trajectory import platform
    from repro.kernels.tunedb import platform_id
    assert platform() == platform_id()
    assert bench_gate._run_platform({"platform": platform()})["backend"] \
        == platform()["backend"]


def test_serve_load_p99_accessor():
    sys.path.insert(0, ROOT)
    from benchmarks.trajectory import serve_load_p99
    run = {"serve_load": [
        {"sessions": 64, "n_bits": 1000, "p99_ms": 8.5},
        {"sessions": 256, "n_bits": 4000, "p99_ms": 33.1},
        {"sessions": 1024, "n_bits": 16000, "p99_ms": 129.9}]}
    assert serve_load_p99(run, 64) == 8.5
    assert serve_load_p99(run, 1024) == 129.9
    assert serve_load_p99(run, 512) == 0.0        # level never ran
    assert serve_load_p99({}, 64) == 0.0          # run predates the sweep


def test_serve_load_gate_inversion_arithmetic():
    """The latency gate is inverted: cur > (1 + tol) * min(stored) fails.
    Pin the arithmetic the gate applies so a sign slip (latency gated
    like throughput) cannot survive."""
    stored = [10.0, 12.0, 11.0]
    tol = 0.2
    base = min(stored)
    ceil = (1.0 + tol) * base
    assert ceil == 12.0
    assert not 11.9 > ceil                        # within tolerance: pass
    assert 12.1 > ceil                            # regression: fail
