import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FrameSpec, STD_K7, framed_decode
from conftest import noisy_llr


def _ber(spec, bits, llr):
    out = np.asarray(framed_decode(jnp.asarray(llr), STD_K7, spec))
    return (out != bits).mean()


def test_parallel_equals_serial_noiseless(rng):
    bits = rng.integers(0, 2, 2048)
    llr = noisy_llr(bits, STD_K7, 60.0, rng)       # ~noiseless
    serial = _ber(FrameSpec(128, 20, 45), bits, llr)
    par = _ber(FrameSpec(128, 20, 45, f0=32, v2s=45), bits, llr)
    assert serial == 0 and par == 0


def test_boundary_start_beats_fixed(rng):
    """Paper Fig. 11: random/fixed traceback start hurts BER; storing the
    per-stage argmax state recovers it."""
    bits = rng.integers(0, 2, 60000)
    llr = noisy_llr(bits, STD_K7, 2.0, rng)
    b = _ber(FrameSpec(256, 20, 45, f0=32, v2s=45, start="boundary"),
             bits, llr)
    f = _ber(FrameSpec(256, 20, 45, f0=32, v2s=20, start="fixed"), bits, llr)
    assert b < f


def test_larger_v2s_improves_parallel_tb(rng):
    """Paper Table III: v2 (subframe overlap) dominates parallel-TB BER."""
    bits = rng.integers(0, 2, 60000)
    llr = noisy_llr(bits, STD_K7, 2.0, rng)
    b_small = _ber(FrameSpec(256, 20, 45, f0=32, v2s=10), bits, llr)
    b_large = _ber(FrameSpec(256, 20, 45, f0=32, v2s=45), bits, llr)
    assert b_large <= b_small


def test_parallel_tb_validation():
    with pytest.raises(ValueError, match="multiple of f0"):
        FrameSpec(128, 20, 20, f0=24, v2s=20).validate()   # 128 % 24 != 0
    with pytest.raises(ValueError, match="exceeds v2"):
        FrameSpec(128, 20, 20, f0=32, v2s=30).validate()   # v2s > v2
