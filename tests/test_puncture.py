import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import STD_K7, encode
from repro.core.pipeline import DecoderConfig, make_decoder
from repro.core.framed import FrameSpec
from repro.core.puncture import (PATTERNS, check_alignment, depuncture,
                                 puncture, punctured_rate)


def test_rates():
    assert punctured_rate("1/2") == 0.5
    assert punctured_rate("2/3") == pytest.approx(2 / 3)
    assert punctured_rate("3/4") == pytest.approx(3 / 4)


def test_puncture_depuncture_inverse(rng):
    n = 96
    x = jnp.asarray(rng.standard_normal((n, 2)).astype(np.float32))
    for rate in ("1/2", "2/3", "3/4"):
        s = puncture(x, rate)
        y = np.asarray(depuncture(s, rate, n))
        mask = np.tile(PATTERNS[rate], (1, n)).T[:n].astype(bool)
        assert np.array_equal(y[mask], np.asarray(x)[mask])
        assert np.all(y[~mask] == 0)          # erased -> neutral zero

def test_alignment_check():
    check_alignment(252, 21, 21, "3/4")
    with pytest.raises(ValueError):
        check_alignment(256, 20, 20, "3/4")


@pytest.mark.parametrize("rate,f,v,snr", [("2/3", 256, 20, 5.0),
                                          ("3/4", 252, 21, 6.0)])
def test_punctured_decode_end_to_end(rng, rate, f, v, snr):
    n = 30000
    bits = rng.integers(0, 2, n)
    coded = np.asarray(encode(jnp.asarray(bits), STD_K7))
    tx = 1.0 - 2.0 * np.asarray(puncture(jnp.asarray(coded), rate))
    sigma = 10.0 ** (-snr / 20.0)
    rx = tx + sigma * rng.standard_normal(tx.shape).astype(np.float32)
    dec = make_decoder(DecoderConfig(spec=FrameSpec(f, v, v), rate=rate))
    out = np.asarray(dec(jnp.asarray(rx), n))
    ber = (out != bits).mean()
    assert ber < 5e-2, ber                     # decodes well above chance
