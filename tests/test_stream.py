"""Streaming decode front-end (core/stream.py, distributed/stream.py):
chunked decode must be bit-identical to single-shot, across backends,
chunk geometries, push raggedness, and frame sharding."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DecoderConfig, FrameSpec, STD_K7, encode,
                        make_decoder, make_stream_decoder, stream_decode)
from repro.channel.sim import awgn, bpsk

SPEC = FrameSpec(f=64, v1=16, v2=20, f0=16, v2s=20)


def _llr(n, rng, snr=3.0):
    bits = jnp.asarray(rng.integers(0, 2, n))
    tx = bpsk(encode(bits, STD_K7).reshape(-1))
    rx = awgn(jax.random.PRNGKey(0), tx, snr)
    return np.asarray(rx).reshape(n, 2), bits


def test_stream_equals_single_shot_ragged_pushes(rng):
    n = 5000
    llr, _ = _llr(n, rng)
    cfg = DecoderConfig(spec=SPEC)
    want = np.asarray(make_decoder(cfg)(jnp.asarray(llr), n))
    dec = make_stream_decoder(cfg, chunk_frames=5)
    got, i = [], 0
    for sz in (1, 77, 640, 64, 3000, n):             # ragged, incl. tiny
        sz = min(sz, n - i)
        got.append(dec.push(llr[i:i + sz]))
        i += sz
        if i >= n:
            break
    got.append(dec.flush())
    got = np.concatenate(got)
    assert got.shape == (n,)
    assert np.array_equal(got, want)


def test_stream_decoder_is_reusable_after_flush(rng):
    cfg = DecoderConfig(spec=SPEC)
    dec = make_stream_decoder(cfg, chunk_frames=3)
    for trial in range(2):
        n = 900 + 137 * trial                        # different tails
        llr, _ = _llr(n, np.random.default_rng(trial))
        want = np.asarray(make_decoder(cfg)(jnp.asarray(llr), n))
        got = np.concatenate([dec.push(llr), dec.flush()])
        assert np.array_equal(got, want), trial


@pytest.mark.parametrize("backend", ["kernel", "kernel_split"])
def test_stream_kernel_backends(rng, backend):
    n = 2000
    llr, _ = _llr(n, rng)
    cfg = DecoderConfig(spec=SPEC, backend=backend, layout="sublane")
    want = np.asarray(make_decoder(cfg)(jnp.asarray(llr), n))
    got = stream_decode(cfg, llr, n, chunk_frames=8)
    assert np.array_equal(got, want)


def test_stream_shorter_than_one_chunk(rng):
    n = 100                                          # < one frame even
    llr, _ = _llr(n, rng)
    cfg = DecoderConfig(spec=SPEC)
    want = np.asarray(make_decoder(cfg)(jnp.asarray(llr), n))
    dec = make_stream_decoder(cfg, chunk_frames=16)
    assert dec.push(llr).size == 0                   # nothing complete yet
    got = dec.flush()[:n]
    assert np.array_equal(got, want)


def test_default_chunk_comes_from_plan():
    """No explicit chunk_frames: the autotuner's DecodePlan sizes the
    chunk as 2 tiles x devices (double buffering geometry)."""
    from repro.kernels.autotune import plan_decode
    cfg = DecoderConfig(spec=SPEC, backend="kernel")
    dec = make_stream_decoder(cfg)
    plan = plan_decode(cfg.trellis, SPEC, pack_survivors=cfg.pack_survivors,
                       radix=cfg.radix, bm_dtype=cfg.bm_dtype,
                       layout=cfg.layout, num_devices=1)
    assert dec.chunk_frames == plan.chunk_frames == 2 * plan.frames_per_tile


def test_stream_decode_punctured_rate(rng):
    """Punctured-rate configs take the punctured symbol stream, exactly
    like make_decoder (the StreamContext depunctures in-stream)."""
    from repro.core.puncture import puncture
    n = 3024
    bits = jnp.asarray(rng.integers(0, 2, n))
    tx = bpsk(puncture(encode(bits, STD_K7), "3/4"))
    rx = np.asarray(awgn(jax.random.PRNGKey(0), tx, 6.0))
    cfg = DecoderConfig(spec=FrameSpec(f=63, v1=21, v2=21, f0=21, v2s=21),
                        rate="3/4")
    want = np.asarray(make_decoder(cfg)(jnp.asarray(rx), n))
    got = stream_decode(cfg, rx, n, chunk_frames=9)
    assert np.array_equal(got, want)
    with pytest.raises(ValueError, match="punctured"):
        stream_decode(cfg, rx)                       # n is required


PUNCTURED_SPECS = {
    "2/3": FrameSpec(f=64, v1=16, v2=20, f0=16, v2s=20),   # period 2
    "3/4": FrameSpec(f=63, v1=21, v2=21, f0=21, v2s=21),   # period 3
}


@pytest.mark.parametrize("rate", ["2/3", "3/4"])
def test_push_raw_punctured_stream_matches_framed_decode(rng, rate):
    """The depuncture-in-push satellite: raw punctured symbols pushed in
    ragged slices through StreamDecoder decode bit-identically to
    framed_decode of the same depunctured stream — no caller-side
    depuncturing, the stream-global pattern phase lives in the context."""
    from repro.core import framed_decode
    from repro.core.puncture import depuncture, puncture
    n = 3024
    bits = jnp.asarray(rng.integers(0, 2, n))
    tx = bpsk(puncture(encode(bits, STD_K7), rate))
    rx = np.asarray(awgn(jax.random.PRNGKey(1), tx, 6.0))
    spec = PUNCTURED_SPECS[rate]
    cfg = DecoderConfig(spec=spec, rate=rate)
    full = depuncture(jnp.asarray(rx), rate, n)
    want = np.asarray(framed_decode(full, STD_K7, spec, n))
    dec = make_stream_decoder(cfg, chunk_frames=7)
    got, i = [], 0
    for sz in (1, 100, 531, 2000, rx.shape[0]):       # ragged raw slices
        sz = min(sz, rx.shape[0] - i)
        got.append(dec.push(rx[i:i + sz]))
        i += sz
        if i >= rx.shape[0]:
            break
    got.append(dec.flush())
    got = np.concatenate(got)[:n]
    assert np.array_equal(got, want)


@pytest.mark.parametrize("rate", ["2/3", "3/4"])
def test_punctured_session_through_server_matches_framed_decode(rng, rate):
    """Same satellite through the serve layer: a punctured session in a
    DecodeServer returns framed_decode's bits for the depunctured
    stream."""
    from repro.core import framed_decode
    from repro.core.puncture import depuncture, puncture
    from repro.serve import DecodeServer, PlanCache
    n = 2016
    bits = jnp.asarray(rng.integers(0, 2, n))
    tx = bpsk(puncture(encode(bits, STD_K7), rate))
    rx = np.asarray(awgn(jax.random.PRNGKey(2), tx, 6.0))
    spec = PUNCTURED_SPECS[rate]
    cfg = DecoderConfig(spec=spec, rate=rate)
    want = np.asarray(framed_decode(depuncture(jnp.asarray(rx), rate, n),
                                    STD_K7, spec, n))
    srv = DecodeServer(cache=PlanCache())
    sid = srv.open_session(cfg, chunk_frames=6)
    half = rx.shape[0] // 2
    srv.push(sid, rx[:half])
    srv.step()
    srv.push(sid, rx[half:])
    got = np.concatenate([srv.poll(sid), srv.close_session(sid)])[:n]
    assert np.array_equal(got, want)


def test_punctured_flush_pads_partial_last_stage(rng):
    """A raw stream cut mid-stage still flushes: the stage whose kept
    symbols are only partly present is emitted with neutral zeros for the
    missing ones — bit-identical to depuncturing the zero-extended
    stream. (Stages whose kept symbols are ALL missing cannot exist from
    the stream's point of view: the decode is simply that much shorter.)"""
    from repro.core import framed_decode
    from repro.core.puncture import PATTERNS, depuncture
    n = 1890
    spec = PUNCTURED_SPECS["3/4"]
    cfg = DecoderConfig(spec=spec, rate="3/4")
    pat = PATTERNS["3/4"]
    m = n * pat.sum() // pat.shape[1]
    raw = rng.standard_normal(m).astype(np.float32)
    # cut inside the last 2-kept stage (phase 0): its stage emits with one
    # real symbol + one zero; the two 1-kept stages after it vanish
    cut = m - 3
    n_eff = n - 2
    ext = np.concatenate([raw[:cut], np.zeros((m - cut,), np.float32)])
    want = np.asarray(framed_decode(depuncture(jnp.asarray(ext), "3/4", n),
                                    STD_K7, spec, n))
    dec = make_stream_decoder(cfg, chunk_frames=5)
    got = np.concatenate([dec.push(raw[:cut]), dec.flush()])
    assert got.shape == (n_eff,)
    assert np.array_equal(got, want[:n_eff])


def test_stream_decoder_custom_decode_frames_memoized_per_instance(rng):
    """An explicit decode_frames override can't share the global plan
    cache (no stable identity), but the instance must still compile each
    window length exactly once — not once per dispatch."""
    from repro.core.pipeline import _build_frame_decoder
    from repro.core.stream import StreamDecoder
    n = 15 * 64
    llr, _ = _llr(n, rng)
    cfg = DecoderConfig(spec=SPEC)
    dec = StreamDecoder(cfg, 5, decode_frames=_build_frame_decoder(cfg))
    fns = set()
    got = []
    for i in range(0, n, 5 * 64):                    # 3 identical chunks
        got.append(dec.push(llr[i:i + 5 * 64]))
        fns.add(id(dec._window_decoder(5)))
    got.append(dec.flush())
    assert len(fns) == 1 and set(dec._local_fns) == {5}
    want = np.asarray(make_decoder(cfg)(jnp.asarray(llr), n))
    assert np.array_equal(np.concatenate(got), want)


def test_kernels_package_lazy_attributes():
    """repro.kernels resolves submodules on attribute access (no eager
    imports — that would re-enter repro.core mid-import)."""
    import repro.kernels as K
    assert K.ops.viterbi_decode_frames is not None
    assert K.ref.unified_decode_frames_ref is not None
    with pytest.raises(AttributeError):
        K.nonexistent_submodule


def test_sharded_frame_decoder_single_device(rng):
    from repro.distributed.stream import frame_mesh
    n = 2000
    llr, _ = _llr(n, rng)
    cfg = DecoderConfig(spec=SPEC)
    want = np.asarray(make_decoder(cfg)(jnp.asarray(llr), n))
    got = stream_decode(cfg, llr, n, chunk_frames=8, mesh=frame_mesh())
    assert np.array_equal(got, want)


SHARDED = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.core import DecoderConfig, FrameSpec, STD_K7, make_decoder
from repro.core.stream import stream_decode
from repro.distributed.stream import frame_mesh

n = 4000
rng = np.random.default_rng(0)
llr = rng.standard_normal((n, 2)).astype(np.float32)
spec = FrameSpec(f=64, v1=16, v2=20, f0=16, v2s=20)
cfg = DecoderConfig(spec=spec)
want = np.asarray(make_decoder(cfg)(jnp.asarray(llr), n))
mesh = frame_mesh()
assert mesh.devices.size == 4, mesh.devices
# chunk_frames=6 is NOT a multiple of 4 devices: exercises shard padding
got = stream_decode(cfg, llr, n, chunk_frames=6, mesh=mesh)
assert np.array_equal(got, want)
print("SHARDED_STREAM_OK")
"""


def test_sharded_stream_multi_device():
    """4 host devices: frame-sharded chunk decode == single-shot, incl.
    chunk counts that don't divide the mesh (shard padding)."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SHARDED], capture_output=True,
                       text=True, timeout=600, env=env,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert "SHARDED_STREAM_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]
