"""Per-kernel correctness sweeps: shapes x dtypes x codes vs the pure-jnp
oracle (ref.py), in interpret mode (CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FrameSpec, STD_K7, encode
from repro.core.framed import frame_llr
from repro.core.trellis import make_trellis
from repro.kernels import ops, ref

from conftest import noisy_llr


def _frames(bits, trellis, spec, rng, snr=3.0, dtype=np.float32):
    llr = noisy_llr(bits, trellis, snr, rng).astype(dtype)
    return frame_llr(jnp.asarray(llr), spec)


@pytest.mark.parametrize("spec", [
    FrameSpec(f=64, v1=20, v2=20),                      # serial tb
    FrameSpec(f=64, v1=20, v2=20, f0=16, v2s=20),       # parallel tb
    FrameSpec(f=64, v1=20, v2=20, f0=8, v2s=16),
    FrameSpec(f=128, v1=0, v2=32, f0=32, v2s=32),       # no left overlap
    FrameSpec(f=96, v1=12, v2=24, f0=24, v2s=20, start="fixed"),
])
def test_unified_kernel_matches_ref(rng, spec):
    bits = rng.integers(0, 2, 1000)
    frames = _frames(bits, STD_K7, spec, rng)
    want = np.asarray(ref.unified_decode_frames_ref(frames, STD_K7, spec))
    got = np.asarray(ops.viterbi_decode_frames(frames, STD_K7, spec,
                                               unified=True))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("spec", [
    FrameSpec(f=64, v1=20, v2=20),
    FrameSpec(f=64, v1=20, v2=20, f0=16, v2s=20),
])
def test_split_kernel_matches_ref(rng, spec):
    bits = rng.integers(0, 2, 600)
    frames = _frames(bits, STD_K7, spec, rng)
    want = np.asarray(ref.unified_decode_frames_ref(frames, STD_K7, spec))
    got = np.asarray(ops.viterbi_decode_frames(frames, STD_K7, spec,
                                               unified=False))
    assert np.array_equal(got, want)


def test_forward_kernel_matches_ref(rng):
    bits = rng.integers(0, 2, 500)
    spec = FrameSpec(f=64, v1=16, v2=16)
    frames = _frames(bits, STD_K7, spec, rng)
    from repro.kernels.viterbi_fwd import forward_frames
    F = frames.shape[0]
    Fp = -(-F // 8) * 8
    padded = jnp.pad(frames, ((0, Fp - F), (0, 0), (0, 0)))
    sel, amax = forward_frames(padded, trellis=STD_K7)
    sel_w, amax_w = ref.forward_frames_ref(padded, STD_K7)
    assert np.array_equal(np.asarray(sel), np.asarray(sel_w))
    assert np.array_equal(np.asarray(amax), np.asarray(amax_w))


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_kernel_dtypes(rng, dtype):
    bits = rng.integers(0, 2, 400)
    spec = FrameSpec(f=64, v1=16, v2=20, f0=16, v2s=20)
    llr = noisy_llr(bits, STD_K7, 4.0, rng)
    frames = frame_llr(jnp.asarray(llr, dtype=dtype), spec)
    want = np.asarray(ref.unified_decode_frames_ref(
        frames.astype(jnp.float32), STD_K7, spec))
    got = np.asarray(ops.viterbi_decode_frames(frames, STD_K7, spec))
    # bf16 quantizes the LLRs before the kernel casts up: identical inputs
    # to both paths, so outputs must match exactly
    assert np.array_equal(got, want)


@pytest.mark.parametrize("k,polys", [(5, (0o23, 0o35)),
                                     (7, (0o171, 0o133)),
                                     (4, (0o13, 0o15, 0o17))])  # beta=3
def test_kernel_other_codes(rng, k, polys):
    tr = make_trellis(k, polys)
    bits = rng.integers(0, 2, 400)
    spec = FrameSpec(f=64, v1=16, v2=16, f0=16, v2s=16)
    frames = _frames(bits, tr, spec, rng, snr=6.0)
    want = np.asarray(ref.unified_decode_frames_ref(frames, tr, spec))
    got = np.asarray(ops.viterbi_decode_frames(frames, tr, spec))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("layout", ["lane", "sublane"])
@pytest.mark.parametrize("pack", [False, True])
@pytest.mark.parametrize("radix", [2, 4])
def test_unified_kernel_knobs_match_ref(rng, pack, radix, layout):
    """Bit-packed survivors, radix-4 ACS, and both memory layouts are
    bit-exact, including the odd-length tail paths (L odd, f0+v2s odd)."""
    bits = rng.integers(0, 2, 640)
    spec = FrameSpec(f=64, v1=20, v2=21, f0=16, v2s=21)   # f0+v2s = 37, odd
    frames = _frames(bits, STD_K7, spec, rng)
    want = np.asarray(ref.unified_decode_frames_ref(frames, STD_K7, spec))
    got = np.asarray(ops.viterbi_decode_frames(
        frames, STD_K7, spec, unified=True, pack_survivors=pack, radix=radix,
        layout=layout))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("layout", ["lane", "sublane"])
@pytest.mark.parametrize("pack", [False, True])
@pytest.mark.parametrize("radix", [2, 4])
def test_split_kernel_knobs_match_ref(rng, pack, radix, layout):
    """The split path streams (possibly packed, possibly sublane-major)
    survivors through HBM and traces back at the JAX level — same bits for
    every knob combo."""
    bits = rng.integers(0, 2, 600)
    spec = FrameSpec(f=64, v1=20, v2=20, f0=16, v2s=20)
    frames = _frames(bits, STD_K7, spec, rng)
    want = np.asarray(ref.unified_decode_frames_ref(frames, STD_K7, spec))
    got = np.asarray(ops.viterbi_decode_frames(
        frames, STD_K7, spec, unified=False, pack_survivors=pack,
        radix=radix, layout=layout))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("unified", [True, False])
@pytest.mark.parametrize("layout", ["lane", "sublane"])
def test_split_serial_traceback_layouts(rng, unified, layout):
    """Serial-traceback specs exercise the batched serial chase in both
    stream layouts (the sublane path has no vmap fallback)."""
    bits = rng.integers(0, 2, 400)
    spec = FrameSpec(f=64, v1=16, v2=16)                  # serial tb
    frames = _frames(bits, STD_K7, spec, rng)
    want = np.asarray(ref.unified_decode_frames_ref(frames, STD_K7, spec))
    got = np.asarray(ops.viterbi_decode_frames(
        frames, STD_K7, spec, unified=unified, layout=layout))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("k,polys", [(4, (0o13, 0o15, 0o17)),   # S=8, beta=3
                                     (5, (0o23, 0o35))])        # S=16
def test_small_state_codes_packed_sublane(rng, k, polys):
    """S < 32 states pack into one zero-padded word; the sublane layout's
    flat (L*1, FT) scratch and word extraction must stay exact."""
    tr = make_trellis(k, polys)
    bits = rng.integers(0, 2, 400)
    spec = FrameSpec(f=64, v1=16, v2=16, f0=16, v2s=16)
    frames = _frames(bits, tr, spec, rng, snr=6.0)
    want = np.asarray(ref.unified_decode_frames_ref(frames, tr, spec))
    for unified in (True, False):
        got = np.asarray(ops.viterbi_decode_frames(
            frames, tr, spec, unified=unified, pack_survivors=True, radix=4,
            layout="sublane"))
        assert np.array_equal(got, want), unified


def test_deep_tile_ft256(rng):
    """frames_per_tile >= 256 (beyond PR-1's exercised range): one grid
    step decodes the whole 256-frame batch in the sublane layout."""
    spec = FrameSpec(f=16, v1=8, v2=12, f0=8, v2s=12)
    bits = rng.integers(0, 2, 16 * 256)
    frames = _frames(bits, STD_K7, spec, rng, snr=5.0)
    assert frames.shape[0] == 256
    want = np.asarray(ref.unified_decode_frames_ref(frames, STD_K7, spec))
    got = np.asarray(ops.viterbi_decode_frames(
        frames, STD_K7, spec, frames_per_tile=256, pack_survivors=True,
        radix=4, layout="sublane"))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("layout", ["lane", "sublane"])
def test_bf16_branch_metrics_decode(rng, layout):
    """bf16 branch metrics are not bit-exact, but at a clean SNR the
    decoded bits must still round-trip, and the knob must work on both
    kernels and layouts (test_ber.py bounds the noisy-channel BER delta)."""
    bits = rng.integers(0, 2, 640)
    spec = FrameSpec(f=64, v1=20, v2=20, f0=16, v2s=20)
    frames = _frames(bits, STD_K7, spec, rng, snr=8.0)
    for unified in (True, False):
        got = np.asarray(ops.viterbi_decode_frames(
            frames, STD_K7, spec, unified=unified, layout=layout,
            bm_dtype="bfloat16"))
        decoded = got.reshape(-1)[:len(bits)]
        assert (decoded != bits).mean() == 0.0, (unified, layout)


@pytest.mark.parametrize("k,polys", [(7, (0o171, 0o133)),
                                     (9, (0o753, 0o561))])
def test_deep_tiles_packed_radix4(rng, k, polys):
    """frames_per_tile >= 32 (the packed-survivor headroom) stays exact for
    K=7 and K=9 — the acceptance-criteria codes."""
    tr = make_trellis(k, polys)
    bits = rng.integers(0, 2, 64 * 6)
    spec = FrameSpec(f=64, v1=16, v2=16, f0=16, v2s=16)
    frames = _frames(bits, tr, spec, rng, snr=5.0)
    want = np.asarray(ref.unified_decode_frames_ref(frames, tr, spec))
    got = np.asarray(ops.viterbi_decode_frames(
        frames, tr, spec, frames_per_tile=32, pack_survivors=True, radix=4))
    assert np.array_equal(got, want)


def test_auto_tile_plan_decodes(rng):
    bits = rng.integers(0, 2, 500)
    spec = FrameSpec(f=64, v1=16, v2=16, f0=16, v2s=16)
    frames = _frames(bits, STD_K7, spec, rng)
    want = np.asarray(ref.unified_decode_frames_ref(frames, STD_K7, spec))
    got = np.asarray(ops.viterbi_decode_frames(
        frames, STD_K7, spec, frames_per_tile="auto", pack_survivors=True,
        radix=4))
    assert np.array_equal(got, want)


def test_forward_kernel_packed_stream(rng):
    """Packed split-kernel survivors == pack_bits(unpacked oracle sel)."""
    from repro.kernels.packing import pack_bits
    from repro.kernels.viterbi_fwd import forward_frames
    bits = rng.integers(0, 2, 500)
    spec = FrameSpec(f=64, v1=16, v2=16)
    frames = _frames(bits, STD_K7, spec, rng)
    Fp = -(-frames.shape[0] // 8) * 8
    padded = jnp.pad(frames, ((0, Fp - frames.shape[0]), (0, 0), (0, 0)))
    sel, amax = forward_frames(padded, trellis=STD_K7, pack_survivors=True)
    sel_w, amax_w = ref.forward_frames_ref(padded, STD_K7)
    assert sel.shape == (Fp, spec.frame_len, 2)      # S=64 -> 2 words
    assert np.array_equal(np.asarray(sel), np.asarray(pack_bits(sel_w)))
    assert np.array_equal(np.asarray(amax), np.asarray(amax_w))


def test_kernel_frame_padding(rng):
    """Frame counts not divisible by the tile size are padded + unpadded."""
    bits = rng.integers(0, 2, 64 * 5)                  # 5 frames, tile=8
    spec = FrameSpec(f=64, v1=16, v2=16)
    frames = _frames(bits, STD_K7, spec, rng)
    assert frames.shape[0] == 5
    want = np.asarray(ref.unified_decode_frames_ref(frames, STD_K7, spec))
    got = np.asarray(ops.viterbi_decode_frames(frames, STD_K7, spec))
    assert got.shape == want.shape and np.array_equal(got, want)
