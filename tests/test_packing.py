"""Unit tests for kernels/packing.py — survivor bit-pack round trips in
both physical layouts (lane-packed and Mosaic-native sublane-packed)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.packing import (BITS, Layout, extract_bit, pack_bits,
                                   packed_width, unpack_bits)


@pytest.mark.parametrize("n", [1, 8, 16, 31, 32, 33, 64, 100, 256])
def test_pack_unpack_roundtrip(rng, n):
    sel = rng.integers(0, 2, size=(5, 7, n))
    packed = pack_bits(jnp.asarray(sel))
    assert packed.shape == (5, 7, packed_width(n))
    assert packed.dtype == jnp.int32
    back = np.asarray(unpack_bits(packed, n))
    assert np.array_equal(back, sel)


@pytest.mark.parametrize("n", [1, 8, 31, 32, 33, 64, 100])
def test_pack_unpack_roundtrip_sublane(rng, n):
    """SUBLANE packs axis -2 (states on sublanes) and leaves the trailing
    payload (frames-on-lanes) axis alone — for any n, incl. n % 32 != 0."""
    sel = rng.integers(0, 2, size=(3, n, 6))
    packed = pack_bits(jnp.asarray(sel), Layout.SUBLANE)
    assert packed.shape == (3, packed_width(n), 6)
    assert packed.dtype == jnp.int32
    back = np.asarray(unpack_bits(packed, n, Layout.SUBLANE))
    assert np.array_equal(back, sel)
    # the two layouts hold identical words, just transposed
    lane = pack_bits(jnp.asarray(sel.swapaxes(-1, -2)))
    assert np.array_equal(np.asarray(lane).swapaxes(-1, -2),
                          np.asarray(packed))


def test_packed_width():
    assert [packed_width(n) for n in (1, 31, 32, 33, 64, 65)] == \
        [1, 1, 1, 2, 2, 3]


def test_layout_matches_numpy_bitorder(rng):
    """State s lands at bit s%32 of word s//32 (contiguous little-endian)."""
    sel = rng.integers(0, 2, size=(64,))
    packed = np.asarray(pack_bits(jnp.asarray(sel)))
    want = np.packbits(sel.astype(np.uint8), bitorder="little")
    assert np.array_equal(packed.view(np.uint8), want)


def test_sign_bit_roundtrip():
    """Bit 31 uses the int32 sign bit; wraparound must keep it exact."""
    sel = np.zeros(32, np.int64)
    sel[31] = 1
    packed = np.asarray(pack_bits(jnp.asarray(sel)))
    assert packed[0] == np.int32(-2**31)
    assert np.array_equal(np.asarray(unpack_bits(jnp.asarray(packed), 32)),
                          sel)
    # same word, sublane orientation
    packed_s = np.asarray(pack_bits(jnp.asarray(sel)[:, None],
                                    Layout.SUBLANE))
    assert packed_s[0, 0] == np.int32(-2**31)


@pytest.mark.parametrize("n", [8, 64, 100])
def test_extract_bit_matches_indexing(rng, n):
    sel = rng.integers(0, 2, size=(4, n))
    packed = pack_bits(jnp.asarray(sel))
    states = jnp.asarray(rng.integers(0, n, size=(4,)), jnp.int32)
    got = np.asarray(extract_bit(packed, states))
    want = sel[np.arange(4), np.asarray(states)]
    assert np.array_equal(got, want)


@pytest.mark.parametrize("n", [8, 33, 64, 100])
def test_extract_bit_matches_indexing_sublane(rng, n):
    sel = rng.integers(0, 2, size=(4, n, 9))
    packed = pack_bits(jnp.asarray(sel), Layout.SUBLANE)
    states = jnp.asarray(rng.integers(0, n, size=(4, 9)), jnp.int32)
    got = np.asarray(extract_bit(packed, states, Layout.SUBLANE))
    i, j = np.mgrid[0:4, 0:9]
    assert np.array_equal(got, sel[i, np.asarray(states), j])


def test_extract_bit_broadcasts(rng):
    sel = rng.integers(0, 2, size=(3, 5, 64))
    packed = pack_bits(jnp.asarray(sel))
    states = jnp.asarray(rng.integers(0, 64, size=(3, 5)), jnp.int32)
    got = np.asarray(extract_bit(packed, states))
    i, j = np.mgrid[0:3, 0:5]
    assert np.array_equal(got, sel[i, j, np.asarray(states)])
    assert BITS == 32
