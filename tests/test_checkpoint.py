"""Durable sessions (PR 8): StreamContext state round-trips, serve
checkpoint/restore bit-identity, crash recovery, circuit breakers +
device failover, and the corrupt-checkpoint rejection contract."""
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import DecoderConfig, FrameSpec, STD_K7, encode
from repro.core.puncture import puncture
from repro.core.stream import STATE_VERSIONS, StreamContext, stream_decode
from repro.channel.sim import awgn, bpsk
from repro.serve import (Breaker, CheckpointError, DecodeServer, Draining,
                         PlanCache, save_checkpoint)
from repro.testing.faults import (FaultInjector, FaultSpec, InjectedCrash)

from _hypothesis_compat import given, settings, st

SPEC = FrameSpec(f=64, v1=16, v2=20)
SPEC34 = FrameSpec(f=63, v1=21, v2=21)


def _rx(n, rate="1/2", seed=0, snr=4.0, trellis=STD_K7):
    """Noisy received stream: (n, 2) soft symbols, or the raw punctured
    flat stream for punctured rates."""
    rng = np.random.default_rng(seed)
    bits = jnp.asarray(rng.integers(0, 2, n))
    coded = encode(bits, trellis)
    tx = bpsk(puncture(coded, rate)) if rate != "1/2" \
        else bpsk(coded.reshape(-1))
    rx = np.asarray(awgn(jax.random.PRNGKey(seed), tx, snr))
    return rx if rate != "1/2" else rx.reshape(n, 2)


def _windows(ctx, pieces, flush):
    """Feed ``pieces`` then (optionally) flush; returns the emitted
    windows as comparable (frames-bytes, n_bits) pairs."""
    out = []
    for p in pieces:
        ctx.append(p)
        out += ctx.take_windows()
    if flush:
        out += ctx.flush_chunks()
    spec = ctx.spec
    return [(w.frames(spec).tobytes(), w.n_bits) for w in out]


# -- StreamContext state round-trip ---------------------------------------
@settings(max_examples=16, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from(["1/2", "3/4"]),
       st.sampled_from(list(STATE_VERSIONS)))
def test_context_state_roundtrip_bit_identical(seed, rate, version):
    """The property the whole durability story rests on: snapshot a
    context mid-stream at a random point of a random push schedule,
    restore it into a FRESH context, feed both the same remaining input —
    every subsequent window (and the flush tail) is bit-identical."""
    rng = np.random.default_rng(seed)
    spec = SPEC if rate == "1/2" else SPEC34
    n = int(rng.integers(2, 14)) * spec.f
    rx = _rx(n, rate, seed=seed % 1000)
    flat = rx.reshape(-1)
    # random ragged cut points (raw symbol granularity — mid-stage cuts
    # for the punctured rate exercise the raw remainder + phase carry)
    k = int(rng.integers(2, 7))
    cuts = np.sort(rng.choice(np.arange(1, flat.shape[0]), k, replace=False))
    pieces = np.split(flat, cuts)
    if rate == "1/2":
        # rate-1/2 pushes are (s, 2) stages; round the cuts to pairs
        pieces = np.split(rx, np.unique(np.clip(cuts // 2, 1, n - 1)))
    cut = int(rng.integers(1, len(pieces)))
    C = int(rng.integers(1, 4))

    ctx = StreamContext(spec, STD_K7.beta, C, rate)
    for p in pieces[:cut]:
        ctx.append(p)
        ctx.take_windows()
    state = ctx.state_dict(version=version)
    state = json.loads(json.dumps(state))       # a real serialization trip

    fresh = StreamContext(spec, STD_K7.beta, C, rate)
    fresh.load_state(state)
    assert fresh.n_in == ctx.n_in and fresh.n_out == ctx.n_out
    got = _windows(fresh, pieces[cut:], flush=True)
    want = _windows(ctx, pieces[cut:], flush=True)
    assert got == want


def test_context_state_rejects_bad_version_geometry_and_crc():
    ctx = StreamContext(SPEC, STD_K7.beta, 2, "1/2")
    ctx.append(_rx(3 * 64, seed=1))
    ctx.take_windows()
    state = ctx.state_dict()
    with pytest.raises(ValueError, match="version"):
        ctx.state_dict(version=99)
    bad = dict(state, version=99)
    with pytest.raises(ValueError, match="version"):
        StreamContext(SPEC, STD_K7.beta, 2, "1/2").load_state(bad)
    # geometry mismatch: different chunk_frames would decode differently
    with pytest.raises(ValueError, match="geometry"):
        StreamContext(SPEC, STD_K7.beta, 3, "1/2").load_state(state)
    with pytest.raises(ValueError, match="geometry"):
        StreamContext(SPEC34, STD_K7.beta, 2, "3/4").load_state(state)
    # v2 carry corruption trips the CRC, and nothing half-loads
    target = StreamContext(SPEC, STD_K7.beta, 2, "1/2")
    corrupt = dict(state, buf="AAAA" + state["buf"][4:])
    with pytest.raises(ValueError, match="CRC"):
        target.load_state(corrupt)
    assert target.n_in == 0                     # untouched by the failure
    with pytest.raises(ValueError, match="state dict"):
        target.load_state({"nonsense": True})


# -- server checkpoint / restore ------------------------------------------
def test_server_checkpoint_restore_bit_identical_with_queued_windows():
    """Kill a server with work at EVERY pipeline position — undelivered
    ready bits, still-queued windows, half-pushed carry — restore in a
    'fresh process', finish both; the restored server's bits match the
    uninterrupted run and the solo stream_decode baseline."""
    cfg12 = DecoderConfig(spec=SPEC)
    cfg34 = DecoderConfig(spec=SPEC34, rate="3/4")
    n = 10 * 64
    rxs = {0: _rx(n, seed=20), 1: _rx(n, seed=21)}
    rx34 = _rx(630, "3/4", seed=22)

    def build():
        srv = DecodeServer(slots=2, cache=PlanCache())
        a = srv.open_session(cfg12, chunk_frames=2)
        b = srv.open_session(cfg12, chunk_frames=2)
        c = srv.open_session(cfg34, chunk_frames=3)
        return srv, (a, b, c)

    srv, (a, b, c) = build()
    srv.push(a, rxs[0][: 6 * 64])
    srv.push(b, rxs[1][: 4 * 64 + 13])          # ragged: carry mid-frame
    srv.push(c, rx34[:301])                     # mid-stage raw remainder
    srv.step()                                   # some launched (depth=1)
    srv.push(a, rxs[0][6 * 64:8 * 64])          # some still queued
    path = "/tmp/test_serve_ckpt.json"
    srv.checkpoint(path)
    assert any(b_.queue for b_ in srv.buckets())  # the cut really had
    # queued windows (the checkpoint must carry them)

    srv2 = DecodeServer.restore(path, cache=PlanCache())
    assert srv2.num_sessions == 3
    finish = [(a, rxs[0][8 * 64:], n, cfg12, np.concatenate([rxs[0]])),
              (b, rxs[1][4 * 64 + 13:], n, cfg12, rxs[1]),
              (c, rx34[301:], 630, cfg34, rx34)]
    outs = {}
    for which, s in (("live", srv), ("restored", srv2)):
        got = {}
        for sid, rest, n_bits, _cfg, _full in finish:
            s.push(sid, rest)
        s.drain()
        for sid, rest, n_bits, _cfg, _full in finish:
            got[sid] = np.concatenate(
                [s.poll(sid), s.close_session(sid)])[:n_bits]
        outs[which] = got
    for sid, _rest, n_bits, cfg, full in finish:
        cf = 2 if cfg is cfg12 else 3
        want = stream_decode(cfg, full, n_bits, chunk_frames=cf)
        assert np.array_equal(outs["live"][sid], want)
        assert np.array_equal(outs["restored"][sid], want)


def test_restore_preserves_metrics_counters_and_uptime():
    cfg = DecoderConfig(spec=SPEC)
    faults = FaultInjector(FaultSpec("launch_error", every=2), seed=0)
    srv = DecodeServer(slots=2, cache=PlanCache(), faults=faults,
                       max_retries=1, backoff_s=0.0)
    sid = srv.open_session(cfg, chunk_frames=2)
    srv.push(sid, _rx(8 * 64, seed=30))
    srv.drain()
    before = srv.metrics_snapshot()
    assert before["totals"]["launch_errors"] > 0
    path = "/tmp/test_serve_ckpt_metrics.json"
    srv.checkpoint(path)
    srv2 = DecodeServer.restore(path, cache=PlanCache())
    after = srv2.metrics_snapshot()
    for c in ("launch_errors", "retries", "degraded", "launches", "bits"):
        assert after["totals"][c] == before["totals"][c], c
    # uptime continues (cumulative story), it does not restart at ~0
    assert after["totals"]["uptime_s"] >= before["totals"]["uptime_s"]
    assert after["checkpoint"] == {"saves": 1, "restores": 1}
    # stage histograms survive too
    assert (after["stages"]["launch_ms"]["count"]
            == before["stages"]["launch_ms"]["count"])


def test_checkpoint_after_tenant_churn_restores():
    """Regression: buckets (and their breakers) outlive their last
    session in the saving server, so a checkpoint taken after normal
    tenant churn (open -> drain -> close) carries breaker state for a
    bucket restore cannot rebuild. The orphan breaker entry must be
    dropped, not rejected as 'unknown bucket' — and a bucket that DOES
    still have a live session keeps its breaker across the trip."""
    cfg12 = DecoderConfig(spec=SPEC)
    cfg34 = DecoderConfig(spec=SPEC34, rate="3/4")
    srv = DecodeServer(slots=2, cache=PlanCache())
    churned = srv.open_session(cfg12, chunk_frames=2)
    srv.push(churned, _rx(4 * 64, seed=80))
    srv.drain()
    srv.close_session(churned)                   # bucket stays in _buckets
    live = srv.open_session(cfg34, chunk_frames=3)
    rx34 = _rx(630, "3/4", seed=81)
    srv.push(live, rx34[:301])
    path = "/tmp/test_serve_ckpt_churn.json"
    srv.checkpoint(path)

    srv2 = DecodeServer.restore(path, cache=PlanCache())
    assert srv2.num_sessions == 1
    # only the live session's bucket came back; its breaker survived
    assert list(srv2.metrics_snapshot()["breakers"].values()) \
        == [{"state": "closed", "trips": 0, "consecutive": 0}]
    # the surviving stream resumes bit-identically...
    srv2.push(live, rx34[301:])
    got = np.concatenate([srv2.poll(live), srv2.close_session(live)])[:630]
    assert np.array_equal(got, stream_decode(cfg34, rx34, 630,
                                             chunk_frames=3))
    # ...and fresh tenants of the churned config admit + decode normally
    rx = _rx(4 * 64, seed=82)
    sid = srv2.open_session(cfg12, chunk_frames=2)
    srv2.push(sid, rx)
    got = np.concatenate([srv2.poll(sid), srv2.close_session(sid)])
    assert np.array_equal(got, stream_decode(cfg12, rx, 4 * 64,
                                             chunk_frames=2))


def test_checkpoint_all_sessions_closed_restores_empty():
    """The reviewer's minimal repro: every session closed, then
    checkpoint — the restore must succeed with zero sessions, not raise
    CheckpointError over the left-behind bucket's breaker state."""
    cfg = DecoderConfig(spec=SPEC)
    srv = DecodeServer(slots=2, cache=PlanCache())
    sid = srv.open_session(cfg, chunk_frames=2)
    srv.push(sid, _rx(4 * 64, seed=83))
    srv.drain()
    srv.close_session(sid)
    path = "/tmp/test_serve_ckpt_churn_empty.json"
    srv.checkpoint(path)
    srv2 = DecodeServer.restore(path, cache=PlanCache())
    assert srv2.num_sessions == 0
    assert srv2.metrics_snapshot()["breakers"] == {}


def test_corrupt_and_mismatched_checkpoints_are_rejected():
    cfg = DecoderConfig(spec=SPEC)
    srv = DecodeServer(cache=PlanCache())
    srv.open_session(cfg, chunk_frames=2)
    path = "/tmp/test_serve_ckpt_bad.json"
    srv.checkpoint(path)
    raw = open(path, "rb").read()

    with pytest.raises(CheckpointError, match="cannot read"):
        DecodeServer.restore(path + ".nope")
    # tampered payload (still valid JSON) -> CRC refusal
    doc = json.loads(raw.decode())
    doc["payload"]["next_sid"] += 1
    open(path, "w").write(json.dumps(doc))
    with pytest.raises(CheckpointError, match="CRC"):
        DecodeServer.restore(path)
    # truncation -> not-JSON refusal
    open(path, "wb").write(raw[: len(raw) // 2])
    with pytest.raises(CheckpointError, match="JSON"):
        DecodeServer.restore(path)
    # schema mismatch -> cross-version refusal
    doc = json.loads(raw.decode())
    doc["schema"] = "repro.serve.checkpoint/v999"
    open(path, "w").write(json.dumps(doc))
    with pytest.raises(CheckpointError, match="schema"):
        DecodeServer.restore(path)
    # not a checkpoint at all
    open(path, "w").write("[1, 2, 3]")
    with pytest.raises(CheckpointError, match="envelope"):
        DecodeServer.restore(path)


def test_checkpoint_corrupt_fault_is_caught_at_restore():
    """The checkpoint_corrupt FaultSpec flips bytes as the file is
    written; the restore path must refuse it — and the previous good
    checkpoint (atomic replace) must still load."""
    cfg = DecoderConfig(spec=SPEC)
    path = "/tmp/test_serve_ckpt_fault.json"
    good = "/tmp/test_serve_ckpt_fault_good.json"
    faults = FaultInjector(FaultSpec("checkpoint_corrupt", after=2), seed=0)
    srv = DecodeServer(cache=PlanCache(), faults=faults)
    srv.open_session(cfg, chunk_frames=2)
    save_checkpoint(srv, good)                   # write #1: clean
    save_checkpoint(srv, path)                   # write #2: corrupted
    with pytest.raises(CheckpointError):
        DecodeServer.restore(path)
    assert DecodeServer.restore(good).num_sessions == 1


def test_drain_refuses_admission_and_pushes_then_snapshots():
    cfg = DecoderConfig(spec=SPEC)
    srv = DecodeServer(slots=2, cache=PlanCache())
    sid = srv.open_session(cfg, chunk_frames=2)
    rx = _rx(6 * 64, seed=40)
    srv.push(sid, rx[: 4 * 64])
    path = "/tmp/test_serve_ckpt_drain.json"
    srv.drain(checkpoint=path)
    assert srv.metrics_snapshot()["draining"]
    with pytest.raises(Draining):
        srv.open_session(cfg, chunk_frames=2)
    with pytest.raises(Draining):
        srv.push(sid, rx[4 * 64:])
    assert srv.poll(sid).size > 0                # polls still drain out
    # the restored server admits again and resumes the stream bit-exactly
    srv2 = DecodeServer.restore(path, cache=PlanCache())
    assert not srv2.metrics_snapshot()["draining"]
    srv2.push(sid, rx[4 * 64:])
    got = np.concatenate([srv2.poll(sid), srv2.close_session(sid)])
    assert srv.poll(sid).size == 0               # nothing new on the old one
    # the checkpoint kept the undelivered bits AND the carry: the restored
    # server's output is the complete stream, bit-equal to solo decode
    want = stream_decode(cfg, rx, 6 * 64, chunk_frames=2)
    assert np.array_equal(got, want)
    srv2.metrics_snapshot()                      # still coherent


# -- circuit breaker + failover -------------------------------------------
def test_breaker_state_machine():
    br = Breaker(threshold=2, cooldown=2)
    assert not br.record_failure() and br.state == "closed"
    assert br.record_failure() and br.state == "open" and br.trips == 1
    br.step()
    assert br.state == "open"
    br.step()
    assert br.state == "half_open"
    assert br.record_failure() and br.trips == 2    # failed probe re-opens
    br.step(), br.step()
    assert br.state == "half_open"
    assert br.record_success() and br.state == "closed"
    rt = Breaker(threshold=2, cooldown=2)
    rt.load_state(br.state_dict())
    assert rt.state_dict() == br.state_dict()
    with pytest.raises(ValueError):
        rt.load_state({"state": "on fire", "consecutive": 0, "trips": 0,
                       "wait": 0})


def test_device_loss_trips_breaker_evacuates_and_recovers_bit_exact():
    """The acceptance scenario: a persistent device loss trips the
    bucket's breaker, its sessions evacuate to the reference-pinned
    failover bucket (trips/evacuated counters + health + breakers all
    say so), decoding continues bit-exactly throughout, and once the
    fault clears a half-open probe re-admits the sessions to the fast
    path."""
    cfg = DecoderConfig(spec=SPEC)
    faults = FaultInjector(FaultSpec("device_loss", after=2, count=4),
                           seed=0)
    srv = DecodeServer(slots=2, cache=PlanCache(), max_retries=2,
                       breaker_threshold=3, breaker_cooldown=2,
                       faults=faults)
    sid = srv.open_session(cfg, chunk_frames=2)
    primary = srv._sessions[sid].bucket
    n = 20 * 64
    rx = _rx(n, seed=50)
    outs, evacuated_seen, recovered = [], False, False
    for pos in range(0, n, 2 * 64):
        srv.push(sid, rx[pos: pos + 2 * 64])
        srv.step()
        outs.append(srv.poll(sid))
        b = srv._sessions[sid].bucket
        evacuated_seen |= b.pinned
        recovered |= (evacuated_seen and not b.pinned)
    outs.append(srv.close_session(sid))
    got = np.concatenate(outs)[:n]
    want = stream_decode(cfg, rx, n, chunk_frames=2)
    assert np.array_equal(got, want)
    assert evacuated_seen, "sessions never moved to the failover bucket"
    assert recovered, "sessions never came back to the fast path"
    assert primary.breaker.state == "closed"
    snap = srv.metrics_snapshot()
    t = snap["totals"]
    assert t["breaker_trips"] >= 1 and t["evacuated"] == 1
    assert t["health"] == "degraded"
    assert snap["breakers"][primary.id]["trips"] == t["breaker_trips"]
    row = next(r for r in snap["buckets"] if r["bucket"] == primary.id)
    assert row["health"] == "degraded" and row["breaker_trips"] >= 1


def test_open_breaker_routes_new_sessions_to_failover():
    cfg = DecoderConfig(spec=SPEC)
    faults = FaultInjector(FaultSpec("device_loss", after=1), seed=0)
    srv = DecodeServer(slots=2, cache=PlanCache(), max_retries=1,
                       breaker_threshold=2, breaker_cooldown=1000,
                       faults=faults)
    s1 = srv.open_session(cfg, chunk_frames=2)
    srv.push(s1, _rx(4 * 64, seed=60))
    srv.step()                                   # trips + evacuates
    assert srv._sessions[s1].bucket.pinned
    s2 = srv.open_session(cfg, chunk_frames=2)   # admitted mid-outage
    assert srv._sessions[s2].bucket.pinned       # straight to failover
    srv.close_session(s1), srv.close_session(s2)


def test_breaker_open_snapshot_keeps_trip_streak_on_late_success():
    """A launch that trips the breaker mid-retry but succeeds on a later
    attempt still fails over (the probe path re-admits) — and the open
    breaker's snapshot keeps reporting the consecutive streak that
    tripped it, not a misleading 0 from the late success."""
    cfg = DecoderConfig(spec=SPEC)
    faults = FaultInjector(FaultSpec("device_loss", after=1, count=2),
                           seed=0)
    srv = DecodeServer(slots=2, cache=PlanCache(), max_retries=2,
                       breaker_threshold=2, breaker_cooldown=1000,
                       backoff_s=0.0, faults=faults)
    sid = srv.open_session(cfg, chunk_frames=2)
    primary = srv._sessions[sid].bucket
    srv.push(sid, _rx(4 * 64, seed=84))
    srv.step()            # fail, fail (trip), late success -> evacuate
    assert srv._sessions[sid].bucket.pinned
    row = srv.metrics_snapshot()["breakers"][primary.id]
    assert row["state"] == "open"
    assert row["consecutive"] >= srv.breaker_threshold
    srv.close_session(sid)


def test_checkpoint_mid_outage_restores_evacuated_placement():
    """A checkpoint taken while a breaker is open must restore the
    breaker open AND the sessions on the failover bucket — not silently
    re-place tenants on the dead device."""
    cfg = DecoderConfig(spec=SPEC)
    faults = FaultInjector(FaultSpec("device_loss", after=1), seed=0)
    srv = DecodeServer(slots=2, cache=PlanCache(), max_retries=1,
                       breaker_threshold=2, breaker_cooldown=1000,
                       faults=faults)
    sid = srv.open_session(cfg, chunk_frames=2)
    n = 8 * 64
    rx = _rx(n, seed=61)
    srv.push(sid, rx[: 4 * 64])
    srv.step()
    assert srv._sessions[sid].bucket.pinned
    path = "/tmp/test_serve_ckpt_outage.json"
    srv.checkpoint(path)
    srv2 = DecodeServer.restore(path, cache=PlanCache())
    assert srv2._sessions[sid].bucket.pinned
    assert any(v["state"] == "open"
               for v in srv2.metrics_snapshot()["breakers"].values())
    srv2.push(sid, rx[4 * 64:])
    got = np.concatenate([srv2.poll(sid), srv2.close_session(sid)])[:n]
    want = stream_decode(cfg, rx, n, chunk_frames=2)
    assert np.array_equal(got, want)


# -- kill-restore-compare chaos -------------------------------------------
def test_kill_restore_compare_deterministic():
    """The CI chaos protocol: seeded crash_at_step kills the server
    mid-workload; the client restores from its last checkpoint, rewinds
    to the matching marker, replays — final bits of every session are
    IDENTICAL to the uninterrupted solo decode. Run twice to pin
    determinism."""
    cfg = DecoderConfig(spec=SPEC)
    n = 16 * 64
    rxs = {0: _rx(n, seed=70), 1: _rx(n, seed=71)}
    path = "/tmp/test_serve_ckpt_crash.json"

    def run():
        faults = FaultInjector(FaultSpec("crash_at_step", after=3, count=1),
                               seed=0)
        srv = DecodeServer(slots=4, cache=PlanCache(), faults=faults)
        sids = {k: srv.open_session(cfg, chunk_frames=2) for k in rxs}
        pos = {k: 0 for k in rxs}
        bits = {k: [] for k in rxs}
        mark = ({k: 0 for k in rxs}, {k: 0 for k in rxs})
        srv.checkpoint(path)
        crashes = 0
        while any(p < n for p in pos.values()):
            try:
                for k, sid in sids.items():
                    if pos[k] < n:
                        srv.push(sid, rxs[k][pos[k]: pos[k] + 2 * 64])
                        pos[k] += 2 * 64
                srv.step()
                for k, sid in sids.items():
                    bits[k].append(srv.poll(sid))
                srv.checkpoint(path)
                mark = ({k: sum(len(x) for x in bits[k]) for k in rxs},
                        dict(pos))
            except InjectedCrash:
                crashes += 1
                srv = DecodeServer.restore(path, cache=PlanCache())
                delivered, posmark = mark
                for k in rxs:
                    acc = (np.concatenate(bits[k]) if bits[k]
                           else np.zeros(0, np.int32))
                    bits[k] = [acc[: delivered[k]]]
                pos = dict(posmark)
        assert crashes == 1
        for k, sid in sids.items():
            bits[k].append(srv.close_session(sid))
        snap = srv.metrics_snapshot()
        return ({k: np.concatenate(bits[k])[:n] for k in rxs},
                snap["checkpoint"]["restores"])

    got1, restores1 = run()
    got2, restores2 = run()
    assert restores1 == restores2 == 1
    for k in rxs:
        want = stream_decode(cfg, rxs[k], n, chunk_frames=2)
        assert np.array_equal(got1[k], want), f"stream {k} diverged"
        assert np.array_equal(got2[k], got1[k]), f"run 2 not deterministic"


# -- bench-gate trajectory resilience (satellite) -------------------------
def test_trajectory_empty_stores_parse_to_no_baseline(tmp_path):
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.trajectory import SCHEMA, load_runs
    cases = {"empty_obj.json": "{}",
             "bare_list.json": "[]",
             "empty_v2.json": json.dumps({"schema": SCHEMA, "runs": []}),
             "no_rows_v1.json": json.dumps({"schema": "kernel_sweep/v1"})}
    for name, content in cases.items():
        p = tmp_path / name
        p.write_text(content)
        assert load_runs(str(p)) == [], name
    # a bare list WITH runs is absorbed, not dropped
    p = tmp_path / "list_runs.json"
    p.write_text(json.dumps([{"rows": [], "full": False}]))
    assert load_runs(str(p)) == [{"rows": [], "full": False}]
    # structurally wrong v2 still raises (history must not vanish green)
    p = tmp_path / "bad_runs.json"
    p.write_text(json.dumps({"schema": SCHEMA, "runs": "oops"}))
    with pytest.raises(ValueError):
        load_runs(str(p))
