"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import (FrameSpec, STD_K7, encode, framed_decode,
                        viterbi_decode)
from repro.core.trellis import make_trellis
from repro.core.puncture import PATTERNS, depuncture, puncture

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


@given(st.integers(0, 2**32 - 1), st.integers(50, 400))
def test_decode_encode_roundtrip_noiseless(seed, n):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, n)
    coded = np.asarray(encode(jnp.asarray(bits), STD_K7))
    llr = jnp.asarray(1.0 - 2.0 * coded.astype(np.float32))
    out = np.asarray(viterbi_decode(llr, STD_K7))
    assert np.array_equal(out, bits)


@given(st.integers(0, 2**32 - 1), st.integers(4, 8))
def test_random_codes_roundtrip(seed, k):
    rng = np.random.default_rng(seed)
    # random polynomials with the MSB set (delay-0 tap present)
    polys = tuple(int(rng.integers(1 << (k - 1), 1 << k)) for _ in range(2))
    tr = make_trellis(k, polys)
    bits = rng.integers(0, 2, 200)
    coded = np.asarray(encode(jnp.asarray(bits), tr))
    llr = jnp.asarray(1.0 - 2.0 * coded.astype(np.float32))
    out = np.asarray(viterbi_decode(llr, tr))
    # catastrophic codes exist among random polys; require <2% disagreement
    # only when the code is non-catastrophic (gcd of polys == 1 heuristic):
    import math
    if math.gcd(polys[0], polys[1]) == 1:
        assert np.array_equal(out, bits)


@given(st.integers(0, 2**32 - 1),
       st.sampled_from(["1/2", "2/3", "3/4"]), st.integers(24, 120))
def test_puncture_inverse_property(seed, rate, n):
    rng = np.random.default_rng(seed)
    period = PATTERNS[rate].shape[1]
    n = (n // period) * period
    x = jnp.asarray(rng.standard_normal((n, 2)).astype(np.float32))
    y = np.asarray(depuncture(puncture(x, rate), rate, n))
    mask = np.tile(PATTERNS[rate], (1, n)).T[:n].astype(bool)
    assert np.array_equal(y[mask], np.asarray(x)[mask])
    assert np.all(y[~mask] == 0)


@given(st.integers(0, 2**32 - 1),
       st.sampled_from([(False, 2), (False, 4), (True, 2), (True, 4)]),
       st.sampled_from([8, 16, "auto"]),
       st.sampled_from(["lane", "sublane"]))
def test_kernel_variants_bit_identical_to_reference(seed, knobs, ft, layout):
    """EVERY float32 kernel configuration — packed/unpacked survivors,
    radix-2/4, lane/sublane layout, any tile size — must decode random
    LLRs bit-identically to the core.decoder-based oracle, on both the
    unified and split paths."""
    from repro.core.framed import frame_llr
    from repro.kernels import ops, ref
    pack, radix = knobs
    rng = np.random.default_rng(seed)
    specs = [FrameSpec(f=64, v1=20, v2=20, f0=16, v2s=20),
             FrameSpec(f=64, v1=16, v2=21, f0=8, v2s=21),
             FrameSpec(f=96, v1=12, v2=24, f0=24, v2s=20, start="fixed")]
    spec = specs[int(rng.integers(0, len(specs)))]
    llr = jnp.asarray(rng.standard_normal((5 * spec.f, 2))
                      .astype(np.float32))          # pure noise: worst case
    frames = frame_llr(llr, spec)
    want = np.asarray(ref.unified_decode_frames_ref(frames, STD_K7, spec))
    unified = bool(seed & 1)                        # alternate the two paths
    got = np.asarray(ops.viterbi_decode_frames(
        frames, STD_K7, spec, unified=unified, frames_per_tile=ft,
        pack_survivors=pack, radix=radix, layout=layout))
    assert np.array_equal(got, want), (spec, pack, radix, ft, unified, layout)


@given(st.integers(0, 2**32 - 1))
def test_stream_decode_equals_single_shot(seed):
    """Chunked streaming decode (random chunk geometry, ragged pushes) is
    bit-identical to the single-shot framed decode of the same stream."""
    from repro.core import DecoderConfig, make_decoder
    from repro.core.stream import stream_decode
    rng = np.random.default_rng(seed)
    n = int(rng.integers(300, 1200))
    spec = FrameSpec(f=64, v1=16, v2=20, f0=16, v2s=20)
    cfg = DecoderConfig(spec=spec)
    llr = rng.standard_normal((n, 2)).astype(np.float32)
    want = np.asarray(make_decoder(cfg)(jnp.asarray(llr), n))
    got = stream_decode(cfg, llr, n,
                        chunk_frames=int(rng.integers(1, 6)),
                        push_size=int(rng.integers(1, 2 * spec.f)))
    assert np.array_equal(got, want)


@given(st.integers(0, 2**32 - 1), st.integers(50, 300))
def test_radix4_forward_bit_identical(seed, n):
    """The fused two-stage ACS is the same arithmetic: sel/sigma/amax and
    the full decode agree bit-for-bit with radix-2, odd lengths included."""
    from repro.core.decoder import viterbi_forward
    rng = np.random.default_rng(seed)
    llr = jnp.asarray(rng.standard_normal((n, 2)).astype(np.float32))
    s2, g2, a2 = viterbi_forward(llr, STD_K7)
    s4, g4, a4 = viterbi_forward(llr, STD_K7, None, 4)
    assert np.array_equal(np.asarray(s2), np.asarray(s4))
    assert np.array_equal(np.asarray(g2), np.asarray(g4))
    assert np.array_equal(np.asarray(a2), np.asarray(a4))
    assert np.array_equal(np.asarray(viterbi_decode(llr, STD_K7)),
                          np.asarray(viterbi_decode(llr, STD_K7, 4)))


@given(st.integers(0, 2**32 - 1), st.integers(1, 300),
       st.sampled_from(["lane", "sublane"]))
def test_pack_roundtrip_property(seed, n, layout):
    from repro.kernels.packing import (Layout, extract_bit, pack_bits,
                                       unpack_bits, packed_width)
    lay = Layout(layout)
    rng = np.random.default_rng(seed)
    if lay is Layout.LANE:
        sel = rng.integers(0, 2, size=(3, n))
        packed = pack_bits(jnp.asarray(sel))
        assert packed.shape == (3, packed_width(n))
        assert np.array_equal(np.asarray(unpack_bits(packed, n)), sel)
    else:
        sel = rng.integers(0, 2, size=(3, n, 4))
        packed = pack_bits(jnp.asarray(sel), lay)
        assert packed.shape == (3, packed_width(n), 4)
        assert np.array_equal(np.asarray(unpack_bits(packed, n, lay)), sel)
        states = jnp.asarray(rng.integers(0, n, size=(3, 4)), jnp.int32)
        got = np.asarray(extract_bit(packed, states, lay))
        i, j = np.mgrid[0:3, 0:4]
        assert np.array_equal(got, sel[i, np.asarray(states), j])


@given(st.integers(0, 2**32 - 1))
def test_framed_decode_permutation_invariance(seed):
    """Decoding is per-frame independent: decoding a stream whose frames are
    decoded jointly equals the full framed decode (vmap correctness)."""
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, 512)
    coded = np.asarray(encode(jnp.asarray(bits), STD_K7))
    llr = 1 - 2 * coded.astype(np.float32)
    llr += 0.3 * rng.standard_normal(llr.shape).astype(np.float32)
    spec = FrameSpec(f=128, v1=16, v2=20)
    full = np.asarray(framed_decode(jnp.asarray(llr), STD_K7, spec))
    # decode the two halves separately at a frame boundary
    a = np.asarray(framed_decode(jnp.asarray(llr[:256 + spec.v2]),
                                 STD_K7, spec, n_out=256))[:256]
    assert np.array_equal(full[:256 - spec.v2], a[:256 - spec.v2])


@given(st.integers(0, 2**32 - 1),
       st.integers(1, 4), st.integers(1, 3), st.integers(1, 3))
def test_rms_norm_custom_vjp_matches_autodiff(seed, b, s, dmul):
    from repro.models.layers import rms_norm
    d = 8 * dmul
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(k1, (b, s, d), jnp.float32)
    w = 1.0 + 0.1 * jax.random.normal(k2, (d,), jnp.float32)
    dy = jax.random.normal(k3, (b, s, d), jnp.float32)

    def ref(x, w):
        xf = x.astype(jnp.float32)
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + 1e-5)
        return (y * w).astype(x.dtype)

    y1, vjp1 = jax.vjp(lambda x, w: rms_norm(x, w, 1e-5), x, w)
    y2, vjp2 = jax.vjp(ref, x, w)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    g1, g2 = vjp1(dy), vjp2(dy)
    np.testing.assert_allclose(np.asarray(g1[0]), np.asarray(g2[0]),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(g1[1]), np.asarray(g2[1]),
                               atol=1e-4)


@given(st.integers(0, 2**32 - 1), st.sampled_from([32, 64]),
       st.integers(1, 2))
def test_blockwise_attention_matches_full(seed, chunk, gmul):
    from repro.models.layers import _sdpa_blockwise, _sdpa_full
    B, S, KV, hd = 2, 128, 2, 16
    H = KV * gmul
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    full = _sdpa_full(q, k, v, causal=True)
    bw = _sdpa_blockwise(q, k, v, chunk)
    np.testing.assert_allclose(np.asarray(bw), np.asarray(full), atol=2e-5)
