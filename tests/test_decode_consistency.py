"""Incremental decode must equal the parallel (teacher-forced) forward —
the core serving-correctness invariant, per family."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.models import layers as L
from repro.models import transformer as T


@pytest.mark.parametrize("arch", ["qwen3_32b", "mamba2_2p7b",
                                  "jamba15_large", "starcoder2_7b",
                                  "qwen3_moe_235b"])
def test_incremental_matches_parallel(arch):
    cfg = dataclasses.replace(get_config(arch, reduced=True), dtype="float32")
    if cfg.moe:   # avoid batch-shape-dependent capacity drops
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_per_choice=float(cfg.moe.num_experts)))
    m = build_model(cfg, remat="none")
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    x, _ = T.forward(params, cfg, toks, remat="none")
    lg_full = L.logits(params["embed"], x)
    cache = m.init_cache(params, B, S)
    step = jax.jit(m.decode)
    outs = []
    for t in range(S):
        lg, cache = step(params, toks[:, t:t + 1], cache)
        outs.append(lg[:, 0])
    lg_inc = jnp.stack(outs, axis=1)
    assert float(jnp.max(jnp.abs(lg_inc - lg_full))) < 2e-5
