import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config
from repro.distributed.sharding import cache_specs, param_specs
from repro.models import build_model


def _leaf(specs, *path):
    node = specs
    for k in path:
        node = node[k]
    return node


def test_param_spec_rules():
    cfg = get_config("qwen3_moe_235b", reduced=True)
    m = build_model(cfg)
    shapes = jax.eval_shape(m.init, jax.random.PRNGKey(0))
    specs = param_specs(shapes)
    assert _leaf(specs, "embed", "tok") == P("model", "data")
    blk = specs["blocks"]["b0"]
    assert blk["mixer"]["wq"] == P(None, "data", "model")
    assert blk["mixer"]["wo"] == P(None, "model", "data")
    assert blk["ff"]["ewg"] == P(None, "model", "data", None)
    assert blk["ff"]["ewd"] == P(None, "model", None, "data")
    assert blk["ln1"] == P()                       # norms replicated
    assert blk["mixer"]["qn"] == P()


def test_param_spec_mamba():
    cfg = get_config("mamba2_2p7b", reduced=True)
    m = build_model(cfg)
    shapes = jax.eval_shape(m.init, jax.random.PRNGKey(0))
    specs = param_specs(shapes)
    mix = specs["blocks"]["b0"]["mixer"]
    assert mix["in_proj"] == P(None, "data", "model")
    assert mix["out_proj"] == P(None, "model", "data")
    assert mix["conv_w"] == P(None, None, "model")
    assert mix["A_log"] == P(None, "model")


def test_shard_data_off():
    cfg = get_config("qwen3_32b", reduced=True)
    m = build_model(cfg)
    shapes = jax.eval_shape(m.init, jax.random.PRNGKey(0))
    specs = param_specs(shapes, shard_data=False)
    assert specs["blocks"]["b0"]["mixer"]["wq"] == P(None, None, "model")


def test_cache_specs_kv_vs_seq():
    """kv-head dim sharded when divisible by the model axis, else the
    sequence dim (sequence-parallel cache)."""
    mesh = Mesh(np.array(jax.devices()).reshape(1, 1), ("data", "model"))
    cache = {"b0": {"k": jax.ShapeDtypeStruct((2, 4, 64, 8, 16), jnp.bfloat16),
                    "v": jax.ShapeDtypeStruct((2, 4, 64, 8, 16), jnp.bfloat16),
                    "idx": jax.ShapeDtypeStruct((2,), jnp.int32)}}
    specs = cache_specs(cache, mesh)
    assert specs["b0"]["k"].spec[3] == "model"     # kv divisible by 1
    assert specs["b0"]["idx"].spec == P()


def test_one_device_end_to_end_sharded_jit():
    """The full sharded train step runs on a 1x1 mesh (the degenerate case
    of the production mesh) — catches spec/tree mismatches."""
    cfg = get_config("qwen3_32b", reduced=True)
    m = build_model(cfg)
    mesh = Mesh(np.array(jax.devices()).reshape(1, 1), ("data", "model"))
    from repro.distributed.sharding import param_shardings
    from repro.optim import adamw, constant
    from repro.train import make_train_step

    params = m.init(jax.random.PRNGKey(0))
    psh = param_shardings(mesh, params)
    params = jax.tree.map(jax.device_put, params, psh)
    opt = adamw(constant(1e-3))
    with mesh:
        step = jax.jit(make_train_step(m, opt))
        b = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32)}
        p2, o2, met = step(params, opt.init(params), b)
    assert bool(jnp.isfinite(met["loss"]))
