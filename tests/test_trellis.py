import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.trellis import make_trellis, popcount, STD_K7


def test_std_k7_tables():
    tr = STD_K7
    assert tr.num_states == 64 and tr.beta == 2
    assert tr.polys == (0o171, 0o133)
    # Fig 1a: from state 0, input 1 -> both output bits are 1 (all taps see 1)
    assert tr.out_bits[0, 0] == 0
    assert tr.out_bits[0, 1] == 0b11


def test_butterfly_consistency():
    tr = STD_K7
    j = np.arange(64)
    for p in (0, 1):
        i = tr.prev_state[j, p]
        b = tr.branch_input[j]
        assert np.all(tr.next_state[i, b] == j)
        assert np.all(tr.prev_out[j, p] == tr.out_bits[i, b])


def test_symmetry_tables():
    tr = STD_K7
    # bm_index/bm_sign encode delta(~o) = -delta(o)
    o = np.arange(4)
    comp = 3 ^ o
    assert np.all(tr.bm_index[o] == tr.bm_index[comp])
    assert np.all(tr.bm_sign[o] == -tr.bm_sign[comp])


def test_popcount():
    x = np.array([0, 1, 3, 255, 0b1010101])
    assert np.all(popcount(x) == [0, 1, 2, 8, 4])


@settings(max_examples=20, deadline=None)
@given(st.integers(3, 9), st.integers(2, 3), st.randoms())
def test_random_code_trellis_invariants(k, beta, rnd):
    polys = tuple(rnd.randrange(1 << (k - 1), 1 << k) for _ in range(beta))
    tr = make_trellis(k, polys)
    S = tr.num_states
    # every state has exactly two successors and two predecessors
    succ = tr.next_state.reshape(-1)
    counts = np.bincount(succ, minlength=S)
    assert np.all(counts == 2)
    j = np.arange(S)
    for p in (0, 1):
        assert np.all(tr.next_state[tr.prev_state[j, p], tr.branch_input] == j)
