import numpy as np

from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM, make_batch


def test_determinism():
    cfg = get_config("qwen3_32b", reduced=True)
    a = make_batch(cfg, DataConfig(4, 32, seed=1), step=5)
    b = make_batch(cfg, DataConfig(4, 32, seed=1), step=5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = make_batch(cfg, DataConfig(4, 32, seed=1), step=6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_host_sharding_partitions_global_batch():
    cfg = get_config("qwen3_32b", reduced=True)
    full = make_batch(cfg, DataConfig(8, 16, seed=3), step=2)
    parts = [make_batch(cfg, DataConfig(8, 16, seed=3, host_id=h,
                                        num_hosts=4), step=2)
             for h in range(4)]
    np.testing.assert_array_equal(
        np.concatenate([p["tokens"] for p in parts]), full["tokens"])


def test_labels_are_shifted_tokens():
    cfg = get_config("qwen3_32b", reduced=True)
    dc = DataConfig(2, 16, mode="learnable")
    b = make_batch(cfg, dc, 0)
    # learnable mode: arithmetic progression -> label = token + 1 mod V
    assert np.all((b["tokens"][:, 1:] == b["labels"][:, :-1]))


def test_iterator_resume():
    cfg = get_config("qwen3_32b", reduced=True)
    it = SyntheticLM(cfg, DataConfig(2, 8), start_step=0)
    seq = [next(it)["tokens"] for _ in range(4)]
    it2 = SyntheticLM(cfg, DataConfig(2, 8), start_step=2)
    np.testing.assert_array_equal(next(it2)["tokens"], seq[2])


def test_vlm_and_encdec_extras():
    vcfg = get_config("phi3_vision_4p2b", reduced=True)
    b = make_batch(vcfg, DataConfig(2, 16), 0)
    assert b["vision_embeds"].shape == (2, vcfg.vision_patches, vcfg.d_model)
    assert np.all(b["labels"][:, :vcfg.vision_patches] == -1)
    ecfg = get_config("seamless_m4t_v2", reduced=True)
    b = make_batch(ecfg, DataConfig(2, 16), 0)
    assert b["frames"].shape == (2, 16, ecfg.d_model)
