import os
import sys

# tests must see exactly ONE device (the dry-run sets its own flags in a
# separate process); never inherit a stray device-count flag.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def std_trellis():
    from repro.core import STD_K7
    return STD_K7


@pytest.fixture()
def rng():
    return np.random.default_rng(0)


def noisy_llr(bits, trellis, snr_db, rng):
    """Encode bits, BPSK, add AWGN -> (n, beta) llr numpy."""
    import jax.numpy as jnp
    from repro.core import encode
    coded = np.asarray(encode(jnp.asarray(bits), trellis))
    tx = 1.0 - 2.0 * coded.astype(np.float32)
    sigma = 10.0 ** (-snr_db / 20.0)
    return tx + sigma * rng.standard_normal(tx.shape).astype(np.float32)
