"""Per-arch smoke tests (required): reduced config of the same family, one
forward + one train step on CPU, asserting shapes + no NaNs; one decode
step per arch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.optim import adamw, constant
from repro.train import make_train_step


def _batch(cfg, B=2, S=16):
    b = {"tokens": jnp.ones((B, S), jnp.int32),
         "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.family == "encdec":
        b["frames"] = jnp.ones((B, S, cfg.d_model), jnp.float32)
    if cfg.vision_patches:
        b["vision_embeds"] = jnp.ones((B, cfg.vision_patches, cfg.d_model),
                                      jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch, reduced=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    opt = adamw(constant(1e-3))
    step = jax.jit(make_train_step(m, opt))
    batch = _batch(cfg)
    loss0 = m.loss(params, batch)
    assert loss0.shape == () and bool(jnp.isfinite(loss0))
    p2, o2, metrics = step(params, opt.init(params), batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = get_config(arch, reduced=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 8
    if cfg.family == "encdec":
        from repro.models import encdec
        mem = encdec.encode(params, cfg, jnp.ones((B, 4, cfg.d_model)))
        cache = m.init_cache(params, B, S, mem)
    else:
        cache = m.init_cache(params, B, S)
    step = jax.jit(m.decode)
    lg, cache2 = step(params, jnp.ones((B, 1), jnp.int32), cache)
    assert lg.shape == (B, 1, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(lg.astype(jnp.float32))))
    # different input token -> different logits (same token would legally
    # give identical outputs: values carry no positional encoding)
    lg2, _ = step(params, jnp.full((B, 1), 2, jnp.int32), cache2)
    assert not np.allclose(np.asarray(lg, np.float32),
                           np.asarray(lg2, np.float32))


def test_prefill_last_logits():
    cfg = get_config("qwen3_32b", reduced=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    lg = m.prefill(params, _batch(cfg))
    assert lg.shape == (2, 1, cfg.padded_vocab)
