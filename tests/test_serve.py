"""Multi-tenant decode service (repro.serve): per-session bit-exactness
vs stream_decode, bucket grouping, the compiled-plan cache (one trace per
(trellis, spec, plan, nframes) bucket), admission/backpressure, and the
per-bucket metrics."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import DecoderConfig, FrameSpec, STD_K7, encode
from repro.core.puncture import puncture
from repro.core.stream import make_stream_decoder, stream_decode
from repro.core.trellis import make_trellis
from repro.channel.sim import awgn, bpsk
from repro.serve import (Backpressure, DecodeServer, PlanCache, ServerFull,
                         bucket_plan)

SPEC = FrameSpec(f=64, v1=16, v2=20, f0=16, v2s=20)
SPEC34 = FrameSpec(f=63, v1=21, v2=21, f0=21, v2s=21)
K5 = make_trellis(5, (0o23, 0o35))


def _stream(trellis, n, rate="1/2", seed=0, snr=4.0):
    """Noisy received stream for ``trellis``: (n, 2) soft symbols, or the
    raw punctured flat stream for punctured rates."""
    rng = np.random.default_rng(seed)
    bits = jnp.asarray(rng.integers(0, 2, n))
    coded = encode(bits, trellis)
    tx = bpsk(puncture(coded, rate)) if rate != "1/2" \
        else bpsk(coded.reshape(-1))
    rx = np.asarray(awgn(jax.random.PRNGKey(seed), tx, snr))
    return rx if rate != "1/2" else rx.reshape(n, 2)


def test_server_eight_sessions_bit_exact_vs_stream_decode():
    """The acceptance criterion: >= 8 concurrent sessions across distinct
    code configs (different K AND a punctured rate), ragged interleaved
    pushes, every session's bits identical to running it alone through
    stream_decode — with exactly one plan-cache trace per (trellis, spec,
    plan, nframes) bucket shape."""
    cfgs = [DecoderConfig(spec=SPEC),                  # K7 rate 1/2
            DecoderConfig(spec=SPEC34, rate="3/4"),    # K7 punctured
            DecoderConfig(trellis=K5, spec=SPEC)]      # K5 rate 1/2
    cache = PlanCache()
    srv = DecodeServer(slots=3, queue_depth=4, cache=cache)
    data = []
    for i in range(8):
        cfg = cfgs[i % 3]
        n = 1800 + 137 * i
        llr = _stream(cfg.trellis, n, cfg.rate, seed=i)
        sid = srv.open_session(cfg, chunk_frames=5)
        data.append((sid, cfg, llr, n))
    assert len({s.bucket.id for s in srv._sessions.values()}) == 3

    pos = [0] * len(data)
    sizes = (311, 1000, 97, 1200)      # ragged; <= queue_depth chunks each
    outs = {sid: [] for sid, _, _, _ in data}
    rnd, done = 0, False
    while not done:
        done = True
        for j, (sid, cfg, llr, n) in enumerate(data):
            if pos[j] >= llr.shape[0]:
                continue
            done = False
            sz = sizes[(j + rnd) % len(sizes)]
            try:
                srv.push(sid, llr[pos[j]:pos[j] + sz])
                pos[j] += sz
            except Backpressure:
                srv.step()
        srv.step()
        for sid, _, _, _ in data:
            outs[sid].append(srv.poll(sid))            # non-blocking
        rnd += 1
    for sid, cfg, llr, n in data:
        outs[sid].append(srv.close_session(sid))
        got = np.concatenate(outs[sid])[:n]
        want = stream_decode(cfg, llr, n, chunk_frames=5)
        assert np.array_equal(got, want), f"session {sid} diverged"
    stats = cache.stats()
    # one trace per distinct (bucket, batch shape); every re-use is a hit
    assert stats["traces"] == stats["misses"] - 3      # 3 frame closures
    assert stats["hits"] > stats["misses"]
    assert srv.num_sessions == 0


def test_one_compile_per_bucket_under_churn():
    """Tenant churn: generations of sessions of one config open, decode,
    and close — the trace count stops at one per batch shape (the full
    2-slot launch and the 1-window close drain), no matter how many
    sessions come and go."""
    cfg = DecoderConfig(spec=SPEC)
    cache = PlanCache()
    srv = DecodeServer(slots=2, cache=cache)
    C, n = 4, 4 * 64
    want = None
    for gen in range(3):
        sids = [srv.open_session(cfg, chunk_frames=C) for _ in range(2)]
        llr = _stream(STD_K7, n + SPEC.v2, seed=0)     # one FULL window
        for sid in sids:
            srv.push(sid, llr)
        assert srv.step() == 2                         # one 2-window launch
        for sid in sids:
            got = np.concatenate([srv.poll(sid), srv.close_session(sid)])
            if want is None:
                want = stream_decode(cfg, llr, n + SPEC.v2, chunk_frames=C)
            assert np.array_equal(got[:n + SPEC.v2], want)
        assert srv.num_sessions == 0
    stats = cache.stats()
    assert stats["traces"] == 2                        # B=2C and B=C shapes
    assert stats["misses"] == 3                        # + the frame closure
    assert stats["hits"] >= 3 * 3 - 2


def test_plan_cache_shared_across_stream_decoders():
    """Two StreamDecoders of the same cfg share one compiled window fn —
    tenant churn at the stream layer never re-traces."""
    cfg = DecoderConfig(spec=SPEC)
    cache = PlanCache()
    llr = _stream(STD_K7, 9 * 64, seed=3)   # one 5-frame chunk + 4-frame tail
    outs = []
    for _ in range(3):
        dec = make_stream_decoder(cfg, chunk_frames=5, cache=cache)
        outs.append(np.concatenate([dec.push(llr), dec.flush()]))
    assert np.array_equal(outs[0], outs[1])
    assert np.array_equal(outs[0], outs[2])
    stats = cache.stats()
    assert stats["traces"] == 2                        # chunk fn + tail fn
    assert stats["hits"] >= 4


def test_punctured_sessions_share_bucket_with_rate_half():
    """Rate is NOT part of the bucket key: a rate-1/2 and a rate-3/4
    session of the same trellis/spec decode in the same bucket (the 3/4
    session depunctures per-session, upstream of the batch)."""
    spec = FrameSpec(f=63, v1=21, v2=21, f0=21, v2s=21)
    c12 = DecoderConfig(spec=spec)
    c34 = DecoderConfig(spec=spec, rate="3/4")
    srv = DecodeServer(slots=2, cache=PlanCache())
    n = 1890
    s12 = srv.open_session(c12, chunk_frames=4)
    s34 = srv.open_session(c34, chunk_frames=4)
    assert len(srv.buckets()) == 1
    llr12 = _stream(STD_K7, n, seed=11)
    raw34 = _stream(STD_K7, n, "3/4", seed=12)
    srv.push(s12, llr12)
    srv.push(s34, raw34)
    srv.drain()
    got12 = np.concatenate([srv.poll(s12), srv.close_session(s12)])[:n]
    got34 = np.concatenate([srv.poll(s34), srv.close_session(s34)])[:n]
    assert np.array_equal(got12, stream_decode(c12, llr12, n, chunk_frames=4))
    assert np.array_equal(got34, stream_decode(c34, raw34, n, chunk_frames=4))


def test_admission_control():
    srv = DecodeServer(max_sessions=2, cache=PlanCache())
    cfg = DecoderConfig(spec=SPEC)
    a = srv.open_session(cfg)
    srv.open_session(cfg)
    with pytest.raises(ServerFull, match="max_sessions"):
        srv.open_session(cfg)
    srv.close_session(a)                               # freeing re-admits
    srv.open_session(cfg)


def test_close_session_tail_longer_than_one_chunk():
    """Regression: a session whose final tail exceeds one chunk (the last
    chunk was only missing v2 right-context stages) must not lose bits —
    flush_chunks splits the tail across full-chunk windows."""
    cfg = DecoderConfig(spec=SPEC)
    srv = DecodeServer(cache=PlanCache())
    n = 330                            # chunk covers 320; tail = 330 > 320
    llr = _stream(STD_K7, n, seed=31)
    sid = srv.open_session(cfg, chunk_frames=5)
    srv.push(sid, llr)
    assert srv._session(sid).inflight == 0             # no complete window
    got = srv.close_session(sid)
    assert got.shape == (n,)
    assert np.array_equal(got, stream_decode(cfg, llr, n, chunk_frames=5))


def test_push_larger_than_queue_depth_raises_before_absorbing():
    """A single push worth more than queue_depth windows is refused UP
    FRONT (retry-safe: nothing was absorbed), and the same data split
    into smaller pushes goes through."""
    cfg = DecoderConfig(spec=SPEC)
    srv = DecodeServer(queue_depth=2, slots=8, cache=PlanCache())
    sid = srv.open_session(cfg, chunk_frames=2)
    n = 10 * 128                       # ~10 windows at 2-frame chunks
    llr = _stream(STD_K7, n, seed=17)
    with pytest.raises(Backpressure, match="split"):
        srv.push(sid, llr)
    assert srv._session(sid).inflight == 0
    for i in range(0, n, 128):         # one chunk at a time, stepping
        srv.push(sid, llr[i:i + 128])
        srv.step()
    got = np.concatenate([srv.poll(sid), srv.close_session(sid)])[:n]
    assert np.array_equal(got, stream_decode(cfg, llr, n, chunk_frames=2))


def test_backpressure_and_recovery():
    srv = DecodeServer(queue_depth=2, slots=8, cache=PlanCache())
    cfg = DecoderConfig(spec=SPEC)
    sid = srv.open_session(cfg, chunk_frames=2)
    chunk = _stream(STD_K7, 2 * 64 + SPEC.v2, seed=5)  # 1+ window per push
    srv.push(sid, chunk)
    srv.push(sid, chunk)
    with pytest.raises(Backpressure, match="step"):
        srv.push(sid, chunk)
    srv.step()                                         # drains the queue
    srv.push(sid, chunk)                               # accepted again
    srv.close_session(sid)


def test_unknown_session_errors():
    srv = DecodeServer(cache=PlanCache())
    with pytest.raises(KeyError, match="no live session"):
        srv.push(99, np.zeros((4, 2), np.float32))
    with pytest.raises(KeyError, match="no live session"):
        srv.poll(99)


def test_session_shorter_than_one_chunk():
    """A stream smaller than one chunk decodes entirely via the padded
    flush window."""
    cfg = DecoderConfig(spec=SPEC)
    srv = DecodeServer(cache=PlanCache())
    n = 100                                            # < one frame even
    llr = _stream(STD_K7, n, seed=7)
    sid = srv.open_session(cfg, chunk_frames=16)
    srv.push(sid, llr)
    assert srv.poll(sid).size == 0                     # nothing complete
    got = srv.close_session(sid)[:n]
    assert np.array_equal(got, stream_decode(cfg, llr, n, chunk_frames=16))


def test_metrics_occupancy_and_latency():
    """One session in a 4-slot bucket: every launch carries 1 window of
    C frames; with the kernel backend the tile padding is charged, with
    the reference backend occupancy is 1.0 by definition. Latency
    percentiles are ordered and positive."""
    cfg = DecoderConfig(spec=SPEC)
    srv = DecodeServer(slots=4, cache=PlanCache())
    sid = srv.open_session(cfg, chunk_frames=4)
    llr = _stream(STD_K7, 16 * 64, seed=9)
    srv.push(sid, llr)
    srv.drain()
    srv.close_session(sid)
    snap = srv.metrics_snapshot()
    assert len(snap["buckets"]) == 1
    row = snap["buckets"][0]
    assert row["launches"] == 2 and row["windows"] == 4   # 3 full + tail
    assert row["occupancy"] == 1.0                        # reference: no pad
    assert 0 < row["p50_ms"] <= row["p99_ms"]
    assert snap["totals"]["bits"] == row["bits"] == 16 * 64
    assert snap["plan_cache"]["traces"] >= 1


def test_kernel_backend_bucket_counts_tile_padding():
    """Kernel-backend buckets charge the ops-level tile padding to
    occupancy: a single 2-frame-chunk session under an 8-frame tile plan
    decodes 6 padding frames per launch."""
    cfg = DecoderConfig(spec=SPEC, backend="kernel", frames_per_tile=8)
    srv = DecodeServer(slots=1, cache=PlanCache())
    sid = srv.open_session(cfg, chunk_frames=2)
    plan = bucket_plan(cfg, chunk_frames=2)
    assert plan.frames_per_tile == 8
    llr = _stream(STD_K7, 4 * 64, seed=13)
    srv.push(sid, llr)
    srv.drain()
    got = np.concatenate([srv.poll(sid), srv.close_session(sid)])
    want = stream_decode(cfg, llr, 4 * 64, chunk_frames=2)
    assert np.array_equal(got, want)
    row = srv.metrics_snapshot()["buckets"][0]
    assert row["pad_frames"] == row["launches"] * 6
    assert row["occupancy"] == pytest.approx(2 / 8)


def test_server_sharded_mesh_single_device():
    """mesh= routes bucket batches through the sharded frame decoder."""
    from repro.distributed.stream import frame_mesh
    cfg = DecoderConfig(spec=SPEC)
    srv = DecodeServer(slots=2, mesh=frame_mesh(), cache=PlanCache())
    n = 1500
    llr = _stream(STD_K7, n, seed=21)
    sid = srv.open_session(cfg, chunk_frames=6)
    srv.push(sid, llr)
    got = np.concatenate([srv.poll(sid), srv.close_session(sid)])[:n]
    assert np.array_equal(got, stream_decode(cfg, llr, n, chunk_frames=6))


def test_bucket_plan_matches_stream_default():
    """A session admitted without chunk_frames buckets under the same
    plan_decode geometry the single-stream front-end uses."""
    from repro.kernels.autotune import plan_decode
    cfg = DecoderConfig(spec=SPEC, backend="kernel")
    plan = bucket_plan(cfg)
    want = plan_decode(STD_K7, SPEC, pack_survivors=cfg.pack_survivors,
                       radix=cfg.radix, bm_dtype=cfg.bm_dtype,
                       layout=cfg.layout, num_devices=1)
    assert plan.cache_key() == want.cache_key()
    assert plan.fingerprint() == want.fingerprint()
    assert len(plan.fingerprint()) == 10
