"""BER behaviour vs the paper's findings (§V-B, Figs 9-11, Tables II-III)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FrameSpec, STD_K7, framed_decode, viterbi_decode
from repro.channel.sim import simulate, theoretical_ber, ebn0_distance_metric


N = 120_000


def _ber(decoder, ebn0, key=1):
    b, _, _ = simulate(jax.random.PRNGKey(key), N, ebn0, decoder)
    return b


def test_full_decoder_tracks_theory():
    dec = lambda l: viterbi_decode(l, STD_K7)
    meas = [_ber(dec, e) for e in (2.0, 3.0)]
    theo = theoretical_ber(np.array([2.0, 3.0]))
    # union bound is an upper bound; ML soft decoding must beat it and be
    # within ~1 dB of it (paper Fig. 9 shows overlap at these SNRs)
    assert meas[0] < theo[0] and meas[1] < theo[1]
    assert meas[0] > theo[0] / 30
    assert meas[0] > meas[1]                     # monotone in SNR


def test_v2_dominates_ber():
    """Paper: 'the effect of v2 is considerable... v1 has almost nothing
    to do with BER' (Fig. 9 / Table II)."""
    b_v2_small = _ber(lambda l: framed_decode(l, STD_K7, FrameSpec(256, 20, 4)), 2.0)
    b_v2_ok = _ber(lambda l: framed_decode(l, STD_K7, FrameSpec(256, 20, 20)), 2.0)
    b_v1_small = _ber(lambda l: framed_decode(l, STD_K7, FrameSpec(256, 4, 20)), 2.0)
    assert b_v2_ok < b_v2_small                   # v2 matters a lot
    assert abs(b_v1_small - b_v2_ok) < 0.3 * max(b_v2_ok, 1e-4) + 2e-4  # v1 doesn't


def test_v2_20_reaches_full_performance():
    """Paper Fig. 9: v2 = 20 achieves theoretical performance for f=256."""
    full = _ber(lambda l: viterbi_decode(l, STD_K7), 2.0)
    framed = _ber(lambda l: framed_decode(l, STD_K7, FrameSpec(256, 20, 20)), 2.0)
    assert framed <= full * 1.15 + 1e-4


def test_bf16_branch_metrics_ber_neutral():
    """Acceptance gate for the bm_dtype knob: storing eq.-9 branch metrics
    in bfloat16 (fp32 path-metric accumulation) must keep BER within 1e-3
    of the fp32 kernel at Eb/N0 >= 2 dB. The quantization error (~0.4% of
    the LLR magnitude) is far below the channel noise at these SNRs."""
    import numpy as np
    from repro.core import DecoderConfig, FrameSpec, make_decoder
    spec = FrameSpec(f=256, v1=20, v2=45, f0=32, v2s=45)
    n = 40_000
    bers = {}
    for dt in ("float32", "bfloat16"):
        cfg = DecoderConfig(spec=spec, backend="kernel", bm_dtype=dt,
                            layout="sublane")
        dec = make_decoder(cfg)
        for ebn0 in (2.0, 3.0):
            b, _, _ = simulate(jax.random.PRNGKey(7), n, ebn0,
                               lambda l: dec(l, n))
            bers[(dt, ebn0)] = b
    for ebn0 in (2.0, 3.0):
        assert abs(bers[("bfloat16", ebn0)]
                   - bers[("float32", ebn0)]) < 1e-3, bers


def test_ebn0_distance_metric():
    grid = np.array([2.0, 2.5, 3.0, 3.5])
    # a curve exactly ON theory has distance ~0; a 0.5dB-shifted one ~0.5
    on = theoretical_ber(grid)
    off = theoretical_ber(grid - 0.5)
    assert abs(ebn0_distance_metric(grid, on)) < 0.06
    assert 0.35 < ebn0_distance_metric(grid, off) < 0.65


def test_soft_beats_hard_decision():
    """Paper §II-C: soft-decision decoding gains ~2.3 dB over hard. We
    check the BER ordering and that soft@E ~ hard@(E+2dB)."""
    dec = lambda l: viterbi_decode(l, STD_K7)
    soft = _ber(dec, 3.0)
    hard, _, _ = simulate(jax.random.PRNGKey(1), N, 3.0, dec, hard=True)
    hard_plus2, _, _ = simulate(jax.random.PRNGKey(1), N, 5.0, dec, hard=True)
    assert soft < hard / 3          # soft is much better at equal Eb/N0
    assert hard_plus2 <= soft * 4 + 2e-5   # ~2 dB closes most of the gap
