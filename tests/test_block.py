"""Intra-frame block-parallel decode (kernels/block.py + the stack above).

The two exactness regimes anchor everything (see kernels/block.py):

* fine-framing equivalence — ``overlap <= min(v1, v2)``: blocking the
  frames of ``spec`` is bit-identical to framing the stream directly
  with ``spec.blocked(B, overlap)``, because every block window lies
  inside its frame's real data;
* degenerate full-overlap — ``overlap >= full_overlap(spec, B)``: every
  block window covers the whole frame, so the blocked decode is
  bit-identical to the unblocked one.

Between the regimes, blocking is the truncated-traceback approximation:
gated here against the exact decode at 1e-3 BER (the bf16 gating pattern
of tests/test_ber.py), with the default overlap ~5 constraint lengths.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import noisy_llr
from repro.core import DecoderConfig, FrameSpec, STD_K7, make_decoder
from repro.core.decoder import viterbi_decode
from repro.core.framed import frame_llr, merge_blocks, reframe_blocks
from repro.core.stream import stream_decode
from repro.kernels import ops
from repro.kernels.autotune import plan_decode
from repro.kernels.block import (BLOCK_LEN_THRESHOLD, choose_block_frames,
                                 default_overlap, full_overlap,
                                 resolve_block)

SERIAL = FrameSpec(f=256, v1=20, v2=20)
# v2s <= overlap <= min(v1, v2) must be satisfiable for the fine-framing
# regime to include a parallel-traceback geometry
PARALLEL = FrameSpec(f=64, v1=16, v2=20, f0=16, v2s=12)


def _frames(spec, n, rng):
    llr = rng.standard_normal((n, 2)).astype(np.float32)
    return jnp.asarray(llr), frame_llr(jnp.asarray(llr), spec)


# -- geometry -------------------------------------------------------------
def test_blocked_spec_geometry():
    sub = SERIAL.blocked(4, 16)
    assert (sub.f, sub.v1, sub.v2) == (64, 16, 16)
    assert not sub.parallel_tb
    subp = PARALLEL.blocked(2, 16)
    assert (subp.f, subp.f0, subp.v2s) == (32, 16, 12)
    assert subp.parallel_tb


def test_blocked_spec_validation_errors():
    with pytest.raises(ValueError, match="not a multiple of block_frames"):
        SERIAL.blocked(3, 10)
    with pytest.raises(ValueError, match="overlap must be >= 0"):
        SERIAL.blocked(4, -1)
    with pytest.raises(ValueError, match="not a multiple of f0"):
        PARALLEL.blocked(8, 12)               # fb=8 not divisible by f0=16
    with pytest.raises(ValueError, match="exceeds the block overlap"):
        PARALLEL.blocked(2, 8)                # v2s=12 > ov=8


def test_reframe_blocks_matches_fine_framing(rng):
    """ov <= min(v1, v2): block windows ARE the fine framing's windows."""
    llr, frames = _frames(SERIAL, 8 * SERIAL.f, rng)
    blocks = reframe_blocks(frames, SERIAL, 4, 16)
    fine = frame_llr(llr, SERIAL.blocked(4, 16))
    assert blocks.shape == fine.shape
    assert np.array_equal(np.asarray(blocks), np.asarray(fine))


def test_merge_blocks_inverts_reframe_shape():
    bits = jnp.arange(8 * 64, dtype=jnp.int32).reshape(8, 64)
    merged = merge_blocks(bits, 4)
    assert merged.shape == (2, 256)
    assert np.array_equal(np.asarray(merged).reshape(-1),
                          np.asarray(bits).reshape(-1))


# -- policy ---------------------------------------------------------------
def test_default_overlap_is_5K_and_covers_v2s():
    assert default_overlap(STD_K7) == 5 * STD_K7.k
    wide = FrameSpec(f=4096, v1=64, v2=64, f0=64, v2s=40)
    assert default_overlap(STD_K7, wide) == 40
    assert default_overlap(STD_K7, PARALLEL) == 35


def test_resolve_block_auto_policy():
    short = FrameSpec(f=256, v1=20, v2=20)
    assert short.f < BLOCK_LEN_THRESHOLD
    assert resolve_block(STD_K7, short, "auto") == (1, 0)
    long = FrameSpec(f=4096, v1=32, v2=32, f0=32, v2s=32)
    bf, ov = resolve_block(STD_K7, long, "auto")
    assert bf > 1 and ov == 35
    fb = long.f // bf
    assert fb >= 2 * ov and fb % long.f0 == 0
    assert bf == choose_block_frames(long, ov)
    # explicit knobs pass through (validated), 1/None/0 mean off
    assert resolve_block(STD_K7, long, 8, 40) == (8, 40)
    for off in (1, None, 0):
        assert resolve_block(STD_K7, long, off) == (1, 0)
    with pytest.raises(ValueError, match="not a multiple"):
        resolve_block(STD_K7, long, 3)


def test_full_overlap_value():
    assert full_overlap(SERIAL, 4) == 3 * 64 + 20
    with pytest.raises(ValueError, match="not a multiple"):
        full_overlap(SERIAL, 3)


# -- kernel-path exactness ------------------------------------------------
@pytest.mark.parametrize("layout", ["lane", "sublane"])
@pytest.mark.parametrize("pack", [False, True])
def test_kernel_fine_framing_equivalence(layout, pack, rng):
    """Blocked kernel decode == the same kernel decoding the fine framing
    directly, per layout and packing (the survivor machinery is reused
    unchanged by blocks)."""
    llr, frames = _frames(SERIAL, 8 * SERIAL.f, rng)
    blocked = ops.viterbi_decode_frames(
        frames, STD_K7, SERIAL, block_frames=4, overlap=16,
        pack_survivors=pack, layout=layout)
    fine = ops.viterbi_decode_frames(
        frame_llr(llr, SERIAL.blocked(4, 16)), STD_K7, SERIAL.blocked(4, 16),
        pack_survivors=pack, layout=layout)
    assert blocked.shape == (8, SERIAL.f)
    assert np.array_equal(np.asarray(blocked).reshape(-1),
                          np.asarray(fine).reshape(-1))


@pytest.mark.parametrize("spec", [FrameSpec(f=64, v1=16, v2=20),
                                  FrameSpec(f=64, v1=16, v2=20,
                                            f0=16, v2s=20)],
                         ids=["serial", "parallel_tb"])
@pytest.mark.parametrize("B", [2, 4])
def test_kernel_degenerate_full_overlap_bit_identity(spec, B, rng):
    """overlap >= full_overlap: blocking must change NOTHING."""
    _, frames = _frames(spec, 8 * spec.f, rng)
    ov = full_overlap(spec, B)
    plain = ops.viterbi_decode_frames(frames, STD_K7, spec)
    blocked = ops.viterbi_decode_frames(frames, STD_K7, spec,
                                        block_frames=B, overlap=ov)
    assert np.array_equal(np.asarray(plain), np.asarray(blocked))


@pytest.mark.parametrize("backend", ["kernel", "kernel_split"])
def test_blocked_backends_match_blocked_reference(backend, rng):
    """All three backends apply the SAME decomposition — bit-identical
    under blocking, so serve degrade/failover to reference is safe."""
    spec = FrameSpec(f=128, v1=16, v2=20)
    n = 4 * spec.f
    llr = jnp.asarray(rng.standard_normal((n, 2)).astype(np.float32))
    kw = dict(spec=spec, block_frames=4, overlap=24)
    want = make_decoder(DecoderConfig(**kw))(llr, n)
    got = make_decoder(DecoderConfig(backend=backend, **kw))(llr, n)
    assert np.array_equal(np.asarray(got), np.asarray(want))


# -- accuracy -------------------------------------------------------------
@pytest.mark.parametrize("snr_db", [2.0, 3.0])
def test_block_ber_within_gate_of_exact(snr_db, rng):
    """The truncated-traceback approximation at the ~5K default overlap
    stays within 1e-3 BER of the EXACT (unframed) Viterbi decode at the
    gated SNR points — the bf16 gating pattern of tests/test_ber.py."""
    spec = FrameSpec(f=4096, v1=32, v2=32, f0=32, v2s=32)
    n = 8 * spec.f
    bits = rng.integers(0, 2, n).astype(np.int32)
    llr = noisy_llr(bits, STD_K7, snr_db, rng)
    exact = np.asarray(viterbi_decode(jnp.asarray(llr), STD_K7))
    ber_exact = float(np.mean(exact != bits))
    dec = make_decoder(DecoderConfig(spec=spec, block_frames="auto"))
    got = np.asarray(dec(jnp.asarray(llr), n))
    ber_blk = float(np.mean(got != bits))
    assert abs(ber_blk - ber_exact) < 1e-3, (ber_blk, ber_exact)


# -- streaming / planning / serve ----------------------------------------
def test_stream_decode_blocked_matches_single_shot(rng):
    spec = FrameSpec(f=2048, v1=32, v2=32)
    cfg = DecoderConfig(spec=spec, backend="kernel", block_frames="auto")
    n = 3 * spec.f
    bits = rng.integers(0, 2, n).astype(np.int32)
    llr = noisy_llr(bits, STD_K7, 3.0, rng)
    one = np.asarray(make_decoder(cfg)(jnp.asarray(llr), n))
    st = stream_decode(cfg, llr, n, chunk_frames=2)
    assert np.array_equal(one, st)


def test_plan_decode_block_roundtrip(rng):
    """plan_decode resolves the auto policy, budgets the tile against the
    derived block spec (frames_per_tile counts blocks), keeps chunk_frames
    in outer frames, and kernel_kwargs() drives the kernel directly."""
    spec = FrameSpec(f=4096, v1=32, v2=32, f0=32, v2s=32)
    seq = plan_decode(STD_K7, spec, layout="sublane")
    blk = plan_decode(STD_K7, spec, layout="sublane", block_frames="auto")
    assert blk.block_frames > 1 and blk.overlap == 35
    assert blk.frames_per_tile > seq.frames_per_tile
    assert blk.cache_key() != seq.cache_key()
    assert blk.chunk_frames >= 1
    kw = blk.kernel_kwargs()
    assert kw["block_frames"] == blk.block_frames
    assert kw["overlap"] == blk.overlap
    _, frames = _frames(spec, 2 * spec.f, rng)
    bits = ops.viterbi_decode_frames(frames, STD_K7, spec, **kw)
    assert bits.shape == (2, spec.f)


def test_decoder_config_validates_block_knobs():
    with pytest.raises(ValueError, match="not a multiple"):
        DecoderConfig(spec=SERIAL, block_frames=3)
    with pytest.raises(ValueError, match="block_frames must be"):
        DecoderConfig(spec=SERIAL, block_frames="sometimes")
    with pytest.raises(ValueError, match="overlap must be"):
        DecoderConfig(spec=SERIAL, overlap=-1)
    DecoderConfig(spec=SERIAL, block_frames="auto")    # sane configs pass
    DecoderConfig(spec=SERIAL, block_frames=4, overlap=16)


def test_serve_low_latency_session(rng):
    """open_session(low_latency=True) engages the auto block policy: the
    session lands in its own bucket (plan identity includes the block
    knobs), decodes on a blocked plan, and returns exactly the bits of
    the equivalent blocked stream_decode."""
    from repro.serve import DecodeServer, PlanCache
    import dataclasses
    spec = FrameSpec(f=2048, v1=32, v2=32)
    cfg = DecoderConfig(spec=spec, backend="kernel")
    n = 2 * spec.f
    bits = rng.integers(0, 2, n).astype(np.int32)
    llr = noisy_llr(bits, STD_K7, 3.0, rng)

    srv = DecodeServer(cache=PlanCache())
    sid_ll = srv.open_session(cfg, chunk_frames=1, low_latency=True)
    sid_seq = srv.open_session(cfg, chunk_frames=1)
    buckets = {s.bucket.id for s in srv._sessions.values()}
    assert len(buckets) == 2, "low-latency session must bucket separately"
    ll_bucket = srv._sessions[sid_ll].bucket
    assert ll_bucket.plan.block_frames > 1
    assert ll_bucket.decode_cfg.block_frames == "auto"
    for sid in (sid_ll, sid_seq):
        srv.push(sid, llr)
        while srv.step():
            pass
    got_ll = np.concatenate([srv.poll(sid_ll), srv.close_session(sid_ll)])[:n]
    got_seq = np.concatenate([srv.poll(sid_seq),
                              srv.close_session(sid_seq)])[:n]
    blk_cfg = dataclasses.replace(cfg, block_frames="auto")
    assert np.array_equal(got_ll, stream_decode(blk_cfg, llr, n,
                                                chunk_frames=1))
    assert np.array_equal(got_seq, stream_decode(cfg, llr, n,
                                                 chunk_frames=1))
