"""End-to-end system test: the paper's verification loop (Fig. 8) through
the FULL production path — depuncture -> framing -> unified Pallas kernel
(interpret) -> stitch — plus an elasticity integration test."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FrameSpec, STD_K7, encode
from repro.core.pipeline import DecoderConfig, make_decoder
from repro.core.puncture import puncture
from repro.channel.sim import bpsk, awgn, ber


def test_sdr_receiver_end_to_end_kernel_path(rng):
    n = 20000
    bits = jnp.asarray(rng.integers(0, 2, n))
    coded = encode(bits, STD_K7)
    tx = bpsk(puncture(coded, "1/2"))
    rx = awgn(jax.random.PRNGKey(0), tx, 3.0)
    cfg = DecoderConfig(
        spec=FrameSpec(f=256, v1=20, v2=45, f0=32, v2s=45),
        backend="kernel", interpret=True)
    dec = make_decoder(cfg)
    out = dec(rx, n)
    b = float(ber(out, bits))
    assert b < 2e-3, b        # ~theory at 3 dB with parallel traceback

    # the split (prior-work) backend decodes identically
    cfg2 = DecoderConfig(
        spec=FrameSpec(f=256, v1=20, v2=45, f0=32, v2s=45),
        backend="kernel_split", interpret=True)
    out2 = make_decoder(cfg2)(rx, n)
    assert np.array_equal(np.asarray(out), np.asarray(out2))


ELASTIC = r"""
import os
os.environ["XLA_FLAGS"]="--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import Mesh
from repro.configs import get_config
from repro.models import build_model
from repro.optim import adamw, constant
from repro.train import make_train_step
from repro.train import checkpoint as ckpt
from repro.distributed.sharding import param_shardings

cfg = get_config("qwen3_32b", reduced=True)
m = build_model(cfg)
opt = adamw(constant(1e-3))
step = make_train_step(m, opt)
b = {"tokens": jnp.ones((4, 16), jnp.int32), "labels": jnp.ones((4, 16), jnp.int32)}

devs = np.array(jax.devices())
mesh8 = Mesh(devs.reshape(4, 2), ("data", "model"))
params = m.init(jax.random.PRNGKey(0))
psh = param_shardings(mesh8, params)
params = jax.tree.map(jax.device_put, params, psh)
opt_state = opt.init(params)
with mesh8:
    params, opt_state, met = jax.jit(step)(params, opt_state, b)
with tempfile.TemporaryDirectory() as d:
    ckpt.save(d, 0, {"params": params, "opt": opt_state})
    # reference: one more step on the ORIGINAL mesh
    with mesh8:
        _, _, met_ref = jax.jit(step)(params, opt_state, b)
    # ELASTIC RESCALE: restore onto a 2-device mesh (6 "failed" devices)
    mesh2 = Mesh(devs[:2].reshape(2, 1), ("data", "model"))
    psh2 = param_shardings(mesh2, params)
    state2 = ckpt.restore(d, 0, {"params": params, "opt": opt_state},
                          {"params": psh2, "opt": {"m": psh2, "v": psh2,
                           "step": jax.NamedSharding(mesh2, jax.sharding.PartitionSpec())}})
    with mesh2:
        p3, o3, met3 = jax.jit(step)(state2["params"], state2["opt"], b)
    assert np.isfinite(float(met3["loss"]))
print("ELASTIC_OK", float(met_ref["loss"]), float(met3["loss"]))
"""


def test_elastic_rescale_across_meshes():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", ELASTIC], capture_output=True,
                       text=True, timeout=600, env=env,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert "ELASTIC_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]
    # losses from the 8-dev and 2-dev meshes agree (same math, resharded)
    _, l8, l2 = r.stdout.split()[:3]
    assert abs(float(l8) - float(l2)) < 5e-2
