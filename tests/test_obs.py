"""Observability layer (repro.obs): span nesting and attributes, the
pay-nothing disabled tracer, histogram percentile accuracy vs
np.percentile, Chrome trace-event schema validity, Prometheus
parseability, and the end-to-end instrumentation of the serve/stream
pipeline (nested launch spans, async chunk overlap, plan_decode
attributes, trace-time kernel events)."""
import json
import re
import threading

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.obs import (Histogram, NULL_TRACER, Tracer, chrome_trace,
                       geometric_bounds, get_tracer, prometheus_text,
                       set_tracer, write_chrome_trace)
from repro.obs.tracer import NullTracer


@pytest.fixture(autouse=True)
def _restore_global_tracer():
    """Every test leaves the process-global tracer disabled — a leaked
    enabled tracer would silently record the rest of the suite."""
    yield
    set_tracer(None)


# ---------------------------------------------------------------- tracer

def test_span_nesting_parent_and_attrs():
    t = Tracer()
    with t.span("outer", a=1):
        with t.span("inner") as sp:
            sp.set(b="two")
    recs = {r.name: r for r in t.spans()}
    assert recs["inner"].parent == "outer"
    assert recs["outer"].parent is None
    assert recs["inner"].attrs == {"b": "two"}
    assert recs["outer"].attrs == {"a": 1}
    assert recs["outer"].dur >= recs["inner"].dur >= 0.0
    # inner completed first, so it is recorded first
    assert [r.name for r in t.spans()] == ["inner", "outer"]


def test_span_records_error_attr_on_exception():
    t = Tracer()
    with pytest.raises(RuntimeError):
        with t.span("boom"):
            raise RuntimeError("x")
    (rec,) = t.spans()
    assert rec.attrs["error"] == "RuntimeError"


def test_async_spans_overlap_and_end_is_idempotent():
    t = Tracer()
    a = t.begin("chunk", i=0)
    b = t.begin("chunk", i=1)
    b.end(bits=64)
    a.end()
    a.end()                                     # second end: no-op
    recs = t.spans()
    assert len(recs) == 2
    assert all(r.kind == "async" for r in recs)
    assert recs[0].sid != recs[1].sid           # distinct pairing ids
    assert recs[0].attrs == {"i": 1, "bits": 64}


def test_events_and_counters():
    t = Tracer()
    with t.span("launch"):
        t.event("retry", attempt=1)
    t.count("hits")
    t.count("hits", 2)
    (ev, sp) = t.spans()
    assert (ev.kind, ev.dur, ev.parent) == ("instant", 0.0, "launch")
    assert t.counters() == {"hits": 3}
    t.clear()
    assert t.spans() == [] and t.counters() == {}


def test_ring_buffer_caps_retained_spans():
    t = Tracer(capacity=8)
    for i in range(20):
        with t.span("s", i=i):
            pass
    recs = t.spans()
    assert len(recs) == 8
    assert [r.attrs["i"] for r in recs] == list(range(12, 20))


def test_tracer_is_thread_safe():
    t = Tracer()

    def work(k):
        for i in range(200):
            with t.span("w", k=k):
                t.count("n")

    threads = [threading.Thread(target=work, args=(k,)) for k in range(4)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert t.counters()["n"] == 800
    assert len(t.spans()) == 800
    # nesting state is per-thread: every span is a root in its own thread
    assert all(r.parent is None for r in t.spans())


def test_null_tracer_pays_nothing():
    """The disabled path returns ONE shared no-op object — no allocation
    per call — and records nothing."""
    n = NullTracer()
    assert n.span("a") is n.span("b")
    assert n.begin("a") is n.span("b")
    with n.span("a") as sp:
        sp.set(x=1)
    n.begin("c").end()
    n.event("e")
    n.count("k")
    assert n.spans() == [] and n.counters() == {}
    assert not n.enabled


def test_global_registry_set_get_restore():
    assert get_tracer() is NULL_TRACER
    t = Tracer()
    prev = set_tracer(t)
    assert prev is NULL_TRACER
    assert get_tracer() is t
    assert set_tracer(None) is t
    assert get_tracer() is NULL_TRACER


# ------------------------------------------------------------- histogram

def test_histogram_percentiles_track_np_percentile():
    rng = np.random.default_rng(0)
    samples = np.exp(rng.normal(1.0, 1.2, size=5000))   # lognormal ms
    h = Histogram.latency_ms()
    h.extend(samples)
    for p in (50, 90, 99):
        exact = float(np.percentile(samples, p))
        got = h.percentile(p)
        # geometric buckets at ratio 2**0.25 => <=~19% bucket resolution
        assert abs(got - exact) / exact < 0.25, (p, got, exact)
    assert h.count == 5000
    assert abs(h.mean() - samples.mean()) / samples.mean() < 1e-6


def test_histogram_degenerate_distribution_is_exact():
    h = Histogram.latency_ms()
    h.extend([3.7] * 100)
    assert h.percentile(50) == pytest.approx(3.7)
    assert h.percentile(99) == pytest.approx(3.7)
    snap = h.snapshot()
    assert snap["count"] == 100 and snap["max"] == pytest.approx(3.7)


def test_histogram_empty_merge_and_bounds_mismatch():
    h = Histogram.latency_ms()
    assert h.percentile(99) == 0.0 and h.mean() == 0.0
    other = Histogram.latency_ms()
    other.extend([1.0, 2.0])
    h.merge(other)
    assert h.count == 2 and h.vmax == 2.0
    with pytest.raises(ValueError):
        h.merge(Histogram.sizes())


def test_geometric_bounds_cover_range():
    b = geometric_bounds(1.0, 100.0, 2.0)
    assert b[0] == 1.0 and b[-1] >= 100.0
    assert all(y == 2 * x for x, y in zip(b, b[1:]))


# ------------------------------------------------------------- exporters

def test_chrome_trace_schema_and_async_pairing(tmp_path):
    t = Tracer()
    with t.span("launch", bucket="b0"):
        with t.span("batch_pack"):
            pass
        t.event("retry", attempt=1)
    h = t.begin("inflight", frames=8)
    h.end()
    t.count("plan_cache_hits", 3)
    path = tmp_path / "trace.json"
    write_chrome_trace(t, str(path))
    obj = json.loads(path.read_text())          # valid JSON on disk
    ev = obj["traceEvents"]
    xs = [e for e in ev if e["ph"] == "X"]
    assert {e["name"] for e in xs} == {"launch", "batch_pack"}
    for e in xs:
        assert e["ts"] >= 0 and e["dur"] >= 0
    pack = next(e for e in xs if e["name"] == "batch_pack")
    assert pack["args"]["parent"] == "launch"
    begins = [e for e in ev if e["ph"] == "b"]
    ends = [e for e in ev if e["ph"] == "e"]
    assert len(begins) == len(ends) == 1
    assert begins[0]["id"] == ends[0]["id"]
    (inst,) = [e for e in ev if e["ph"] == "i"]
    assert inst["name"] == "retry"
    assert obj["otherData"]["counters"] == {"plan_cache_hits": 3}


def test_chrome_trace_stringifies_exotic_attr_values():
    t = Tracer()
    with t.span("s", shape=(4, 2), arr=np.arange(2)):
        pass
    obj = chrome_trace(t)
    args = obj["traceEvents"][-1]["args"]
    assert args["shape"] == "(4, 2)"
    assert isinstance(args["arr"], str)
    json.dumps(obj)                             # everything serializable


_EXPO_LINE = re.compile(
    r'^(# TYPE [a-zA-Z_:][a-zA-Z0-9_:]* (counter|gauge|histogram)'
    r'|[a-zA-Z_:][a-zA-Z0-9_:]*(\{[a-zA-Z0-9_]+="[^"]*"'
    r'(,[a-zA-Z0-9_]+="[^"]*")*\})? -?[0-9.e+-]+)$')


def test_prometheus_text_parses_line_by_line():
    snap = {"totals": {"launches": 4, "mbps": 1.25, "health": "ok"},
            "sessions": 2,
            "buckets": [{"bucket": "K7-f64", "launches": 4,
                         "p50_ms": 0.5, "last_error": "boom \"q\""}],
            "stages": {"launch_ms": {"count": 4, "p50": 0.4, "p99": 0.9,
                                     "max": 1.0, "mean": 0.5, "total": 2.0}},
            "plan_cache": {"entries": 2, "hits": 5, "misses": 2,
                           "traces": 2, "build_ms": 1.5}}
    text = prometheus_text(snap)
    lines = text.strip().split("\n")
    assert lines, "empty exposition"
    for line in lines:
        assert _EXPO_LINE.match(line), f"unparseable line: {line!r}"
    assert "# TYPE repro_serve_launches counter" in lines
    assert "repro_serve_mbps 1.25" in lines
    assert any(l.startswith('repro_serve_bucket_launches{bucket="K7-f64"}')
               for l in lines)
    assert any('stage="launch_ms"' in l and 'stat="p99"' in l
               for l in lines)
    # non-numeric fields (health, last_error) never reach the exposition
    assert "health" not in text and "boom" not in text


def test_prometheus_text_emits_true_histograms():
    """`stages_hist` must come out as real Prometheus histogram series:
    cumulative `_bucket{le=...}` samples ending at le="+Inf" whose count
    equals `_count`, plus `_sum` — the shape histogram_quantile() needs."""
    snap = {"totals": {},
            "stages_hist": {
                "queue_wait_ms": {"buckets": [[0.5, 2], [2.0, 5],
                                              ["+Inf", 7]],
                                  "sum": 6.25, "count": 7},
                "launch_ms": {"buckets": [[1.0, 1], ["+Inf", 1]],
                              "sum": 0.8, "count": 1}}}
    text = prometheus_text(snap)
    lines = text.strip().split("\n")
    for line in lines:
        assert _EXPO_LINE.match(line), f"unparseable line: {line!r}"
    # one TYPE header for the whole family, even with two stages
    assert lines.count("# TYPE repro_serve_stage_ms histogram") == 1
    q = [l for l in lines if 'stage="queue_wait_ms"' in l]
    assert 'repro_serve_stage_ms_bucket{le="0.5",stage="queue_wait_ms"} 2' \
        in q
    assert 'repro_serve_stage_ms_bucket{le="2.0",stage="queue_wait_ms"} 5' \
        in q
    assert 'repro_serve_stage_ms_bucket{le="+Inf",stage="queue_wait_ms"} 7' \
        in q
    assert 'repro_serve_stage_ms_sum{stage="queue_wait_ms"} 6.25' in q
    assert 'repro_serve_stage_ms_count{stage="queue_wait_ms"} 7' in q
    # counts are cumulative (monotone non-decreasing up to +Inf == _count)
    counts = [int(l.rsplit(" ", 1)[1]) for l in q if "_bucket{" in l]
    assert counts == sorted(counts) and counts[-1] == 7


def test_server_snapshot_histograms_round_trip_exposition():
    """End to end: a served workload's metrics_snapshot() carries
    stages_hist, and its exposition parses with cumulative buckets."""
    srv, sids = _serve_workload(None)
    snap = srv.metrics_snapshot()
    hists = snap["stages_hist"]
    for stage in ("queue_wait_ms", "launch_ms", "retire_ms"):
        h = hists[stage]
        assert h["count"] > 0
        assert h["buckets"][-1][0] == "+Inf"
        assert h["buckets"][-1][1] == h["count"]
    json.dumps(snap)                    # "+Inf" as string: strict JSON
    text = prometheus_text(snap)
    for line in text.strip().split("\n"):
        assert _EXPO_LINE.match(line), f"unparseable line: {line!r}"
    assert "# TYPE repro_serve_stage_ms histogram" in text


# -------------------------------------------------- pipeline integration

def _serve_workload(trace, faults=None, **srv_kw):
    from repro.core import DecoderConfig, FrameSpec
    from repro.serve import DecodeServer, PlanCache
    spec = FrameSpec(f=64, v1=16, v2=20, f0=16, v2s=20)
    cfg = DecoderConfig(spec=spec)
    rng = np.random.default_rng(0)
    n = 2 * 5 * spec.f
    rx = rng.standard_normal((n, 2)).astype(np.float32)
    srv = DecodeServer(slots=2, cache=PlanCache(), trace=trace,
                       faults=faults, **srv_kw)
    sids = [srv.open_session(cfg, chunk_frames=5) for _ in range(2)]
    for r in range(2):
        for sid in sids:
            srv.push(sid, rx[r * (n // 2):(r + 1) * (n // 2)])
        while srv.step():
            pass
    return srv, sids


def test_server_spans_nest_and_stage_breakdown_lands_in_snapshot():
    t = Tracer()
    srv, sids = _serve_workload(t)
    for sid in sids:
        srv.close_session(sid)
    names = {r.name for r in t.spans()}
    assert {"push", "launch", "batch_pack", "launch_attempt",
            "retire", "inflight"} <= names
    by_name = {}
    for r in t.spans():
        by_name.setdefault(r.name, []).append(r)
    assert all(r.parent == "launch" for r in by_name["batch_pack"])
    assert all(r.parent == "launch" for r in by_name["launch_attempt"])
    assert all(r.kind == "async" for r in by_name["inflight"])
    snap = srv.metrics_snapshot()
    stages = snap["stages"]
    for stage in ("queue_wait_ms", "batch_pack_ms", "launch_ms",
                  "retire_ms"):
        assert stages[stage]["count"] > 0, stage
    tot = snap["totals"]
    assert tot["mbps"] > 0 and tot["uptime_s"] > 0
    assert all(row["uptime_s"] > 0 for row in snap["buckets"])


def test_server_retry_and_degrade_spans_under_faults():
    from repro.testing import FaultInjector, FaultSpec
    t = Tracer()
    faults = FaultInjector(FaultSpec("launch_error", every=1), seed=0)
    srv, sids = _serve_workload(t, faults=faults, max_retries=1,
                                backoff_s=0.0)
    for sid in sids:
        srv.close_session(sid)
    names = {r.name for r in t.spans()}
    assert "retry" in names or "degrade" in names
    attempts = [r for r in t.spans() if r.name == "launch_attempt"]
    assert any(r.attrs.get("attempt", 0) > 0 or "error" in r.attrs
               for r in attempts)


def test_stream_decoder_emits_async_chunk_spans():
    from repro.core import DecoderConfig, FrameSpec
    from repro.core.stream import make_stream_decoder
    t = Tracer()
    spec = FrameSpec(f=64, v1=16, v2=20, f0=16, v2s=20)
    dec = make_stream_decoder(DecoderConfig(spec=spec), chunk_frames=4,
                              trace=t)
    rng = np.random.default_rng(0)
    n = 3 * 4 * spec.f
    out = np.concatenate([
        dec.push(rng.standard_normal((n, 2)).astype(np.float32)),
        dec.flush()])
    assert out.size == n
    chunks = [r for r in t.spans() if r.name == "chunk"]
    assert len(chunks) == 3 and all(r.kind == "async" for r in chunks)
    assert {r.name for r in t.spans()} >= {"push", "flush", "dispatch"}


def test_plan_decode_span_carries_chosen_plan_and_vmem():
    from repro.core import FrameSpec, STD_K7
    from repro.kernels.autotune import plan_decode
    t = Tracer()
    set_tracer(t)
    spec = FrameSpec(f=256, v1=20, v2=45, f0=32, v2s=45)
    plan = plan_decode(STD_K7, spec, layout="auto")
    (rec,) = [r for r in t.spans() if r.name == "plan_decode"]
    a = rec.attrs
    assert a["kernel"] == "unified"
    assert a["frames_per_tile"] == plan.frames_per_tile
    assert a["chunk_frames"] == plan.chunk_frames
    assert a["vmem_bytes"] > 0 and a["vmem_budget"] > 0
    assert a["fits"] is True
    assert a["fingerprint"] == plan.fingerprint()


def test_kernel_trace_event_fires_once_per_compile():
    from repro.core import FrameSpec, STD_K7
    from repro.core.framed import frame_llr
    from repro.kernels import ops
    t = Tracer()
    set_tracer(t)
    spec = FrameSpec(f=64, v1=16, v2=20, f0=16, v2s=20)
    rng = np.random.default_rng(0)
    llr = jnp.asarray(rng.standard_normal((8 * spec.f, 2)).astype(np.float32))
    frames = frame_llr(llr, spec)
    for _ in range(3):                       # re-launches hit the jit cache
        ops.viterbi_decode_frames(frames, STD_K7, spec,
                                  frames_per_tile=8).block_until_ready()
    evs = [r for r in t.spans() if r.name == "kernel_trace"]
    assert len(evs) == 1                     # one real compile
    assert evs[0].attrs["kernel"] == "unified"
    assert evs[0].attrs["frames_per_tile"] == 8
    assert t.counters()["kernel_traces"] == 1


def test_plan_cache_counts_hits_misses_and_build_time():
    from repro.core import DecoderConfig, FrameSpec
    from repro.serve.plan_cache import PlanCache
    t = Tracer()
    set_tracer(t)
    cache = PlanCache()
    cfg = DecoderConfig(spec=FrameSpec(f=64, v1=16, v2=20, f0=16, v2s=20))
    cache.frame_decoder(cfg)
    cache.frame_decoder(cfg)
    c = t.counters()
    assert c["plan_cache_misses"] == 1 and c["plan_cache_hits"] == 1
    assert any(r.name == "plan_build" for r in t.spans())
    assert cache.stats()["build_ms"] >= 0.0


def test_record_fault_rejects_unknown_counter():
    from repro.serve.metrics import BucketMetrics
    m = BucketMetrics("b0")
    with pytest.raises(ValueError, match="unknown fault counter"):
        m.record_fault("not_a_counter")
    m.record_fault("retries", error="e1", n=2)
    assert m.retries == 2 and m.last_error == "e1"
