import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FrameSpec, STD_K7, encode, framed_decode,
                        viterbi_decode)
from repro.core.encoder import encode_bits
from repro.core.trellis import make_trellis

from conftest import noisy_llr


def test_encoder_matches_numpy_oracle(rng):
    bits = rng.integers(0, 2, 500)
    a = np.asarray(encode(jnp.asarray(bits), STD_K7))
    b = encode_bits(bits, STD_K7)
    assert np.array_equal(a, b)


def test_noiseless_roundtrip(rng):
    bits = rng.integers(0, 2, 400)
    coded = np.asarray(encode(jnp.asarray(bits), STD_K7))
    llr = 1.0 - 2.0 * coded.astype(np.float32)
    out = np.asarray(viterbi_decode(jnp.asarray(llr), STD_K7))
    assert np.array_equal(out, bits)


def test_hard_decision_with_errors(rng):
    """Flip a few coded bits: ML decoding must still recover (t < dfree/2)."""
    bits = rng.integers(0, 2, 300)
    coded = np.asarray(encode(jnp.asarray(bits), STD_K7)).copy()
    flat = coded.reshape(-1)
    flat[[50, 200, 400]] ^= 1          # 3 isolated errors, dfree=10
    llr = 1.0 - 2.0 * coded.astype(np.float32)
    out = np.asarray(viterbi_decode(jnp.asarray(llr), STD_K7))
    assert np.array_equal(out, bits)


@pytest.mark.parametrize("f,v1,v2", [(64, 20, 20), (128, 32, 32),
                                     (256, 20, 24)])
def test_framed_equals_full_noiseless(rng, f, v1, v2):
    bits = rng.integers(0, 2, 1000)
    coded = np.asarray(encode(jnp.asarray(bits), STD_K7))
    llr = jnp.asarray(1.0 - 2.0 * coded.astype(np.float32))
    out = np.asarray(framed_decode(llr, STD_K7, FrameSpec(f=f, v1=v1, v2=v2)))
    assert np.array_equal(out, bits)


def test_framed_noisy_close_to_full(rng):
    bits = rng.integers(0, 2, 20000)
    llr = jnp.asarray(noisy_llr(bits, STD_K7, 3.0, rng))
    full = np.asarray(viterbi_decode(llr, STD_K7))
    framed = np.asarray(framed_decode(llr, STD_K7, FrameSpec(256, 20, 20)))
    ber_full = (full != bits).mean()
    ber_framed = (framed != bits).mean()
    assert ber_framed <= ber_full + 5e-4   # paper: v2=20 reaches theory


def test_other_code_k5(rng):
    tr = make_trellis(5, (0o23, 0o35))
    bits = rng.integers(0, 2, 300)
    coded = np.asarray(encode(jnp.asarray(bits), tr))
    llr = jnp.asarray(1.0 - 2.0 * coded.astype(np.float32))
    out = np.asarray(viterbi_decode(llr, tr))
    assert np.array_equal(out, bits)
