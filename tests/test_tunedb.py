"""Tests for the disk-backed measured-autotune DB (kernels/tunedb.py)
and the ``plan_decode(measure=True)`` timing pass — the observatory PR's
acceptance criteria:

  * measured timings round-trip across processes: a second process with
    the same fingerprint + platform reuses the cache with ZERO
    re-measurement (verified via tracer counters and stats());
  * a changed fingerprint or device kind re-measures;
  * a corrupt DB file is discarded with a structured TuneDBWarning,
    never a crash.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.core import FrameSpec, STD_K7
from repro.kernels.autotune import measure_plan, plan_decode
from repro.kernels.tunedb import (SCHEMA, TuneDB, TuneDBWarning,
                                  default_path, platform_id, platform_key)
from repro.obs.tracer import Tracer, set_tracer

SPEC = FrameSpec(f=64, v1=16, v2=20, f0=16, v2s=20)

#: Smallest honest measured plan_decode call: pinned tile => exactly one
#: candidate, one rep, a 4-frame launch.
MEASURE_KW = dict(measure=True, measure_reps=1, chunk_frames=4,
                  frames_per_tile=8)


@pytest.fixture
def db_path(tmp_path, monkeypatch):
    """Point the default DB location (env override) into tmp."""
    p = str(tmp_path / "tunedb.json")
    monkeypatch.setenv("REPRO_TUNE_DB", p)
    return p


def test_default_path_env_override(db_path):
    assert default_path() == db_path
    db = TuneDB()
    assert db.path == db_path


def test_platform_key_includes_jax_version():
    pid = platform_id()
    assert set(pid) == {"backend", "device_kind", "jax_version"}
    key = platform_key(pid)
    assert key.count("/") == 2 and pid["jax_version"] in key
    # a different device kind is a DIFFERENT key (re-measure trigger)
    other = dict(pid, device_kind="weird-accelerator")
    assert platform_key(other) != key


def test_measure_plan_record_shape(db_path):
    plan = plan_decode(STD_K7, SPEC, frames_per_tile=8, chunk_frames=4)
    rec = measure_plan(STD_K7, SPEC, plan, reps=1)
    assert rec["ms"] > 0 and rec["mbps"] > 0
    assert rec["frames"] == plan.chunk_frames
    assert rec["fingerprint"] == plan.fingerprint()
    assert rec["interpret"] is (platform_id()["backend"] == "cpu")


def test_round_trip_second_instance_zero_remeasure(db_path):
    """A fresh TuneDB instance on the same file (the in-process model of
    a second process) must serve every candidate from cache: zero
    measures, all hits — and the tracer counters must say so."""
    db1 = TuneDB()
    p1 = plan_decode(STD_K7, SPEC, tunedb=db1, **MEASURE_KW)
    s1 = db1.stats()
    assert s1["measures"] >= 1 and s1["entries"] >= 1

    t = Tracer()
    set_tracer(t)
    try:
        db2 = TuneDB()
        p2 = plan_decode(STD_K7, SPEC, tunedb=db2, **MEASURE_KW)
    finally:
        set_tracer(None)
    s2 = db2.stats()
    assert s2["measures"] == 0, "second instance re-measured a cached plan"
    assert s2["hits"] >= 1 and s2["misses"] == 0
    assert p2.cache_key() == p1.cache_key()
    counters = t.counters()
    assert counters.get("tunedb_hits", 0) >= 1
    assert "tunedb_measures" not in counters
    assert "tunedb_misses" not in counters


def test_round_trip_across_real_processes(db_path):
    """The acceptance criterion verbatim: a SECOND PROCESS with the same
    fingerprint + platform reuses the cached timing with zero
    re-measurement, visible in its tracer counters."""
    db = TuneDB()
    p = plan_decode(STD_K7, SPEC, tunedb=db, **MEASURE_KW)
    assert db.stats()["measures"] >= 1
    prog = (
        "import json\n"
        "from repro.core import FrameSpec, STD_K7\n"
        "from repro.kernels.autotune import plan_decode\n"
        "from repro.kernels.tunedb import TuneDB\n"
        "from repro.obs.tracer import Tracer, set_tracer\n"
        "t = Tracer(); set_tracer(t)\n"
        "db = TuneDB()\n"
        "spec = FrameSpec(f=64, v1=16, v2=20, f0=16, v2s=20)\n"
        "p = plan_decode(STD_K7, spec, measure=True, tunedb=db,\n"
        "                measure_reps=1, chunk_frames=4, frames_per_tile=8)\n"
        "print(json.dumps({'stats': db.stats(), 'counters': t.counters(),\n"
        "                  'fp': p.fingerprint()}))\n")
    out = subprocess.run([sys.executable, "-c", prog], check=True,
                         capture_output=True, text=True)
    got = json.loads(out.stdout.strip().splitlines()[-1])
    assert got["fp"] == p.fingerprint()
    assert got["stats"]["measures"] == 0, \
        "second process re-measured a cached plan"
    assert got["stats"]["hits"] >= 1 and got["stats"]["misses"] == 0
    assert got["counters"].get("tunedb_hits", 0) >= 1
    assert "tunedb_measures" not in got["counters"]


def test_changed_fingerprint_remeasures(db_path):
    db = TuneDB()
    plan_decode(STD_K7, SPEC, tunedb=db, **MEASURE_KW)
    before = db.stats()["measures"]
    # radix is part of cache_key() -> different fingerprint -> cache miss
    plan_decode(STD_K7, SPEC, tunedb=db, radix=2, **MEASURE_KW)
    assert db.stats()["measures"] > before


def test_changed_device_kind_remeasures(db_path, monkeypatch):
    db = TuneDB()
    plan_decode(STD_K7, SPEC, tunedb=db, **MEASURE_KW)
    before = db.stats()["measures"]
    # same fingerprint, different device kind: the cached timing must
    # not be trusted (backend stays 'cpu' so the kernel still interprets)
    import repro.kernels.autotune as autotune
    fake = dict(platform_id(), device_kind="other-cpu")
    monkeypatch.setattr(autotune, "platform_id", lambda: fake)
    plan_decode(STD_K7, SPEC, tunedb=db, **MEASURE_KW)
    stats = db.stats()
    assert stats["measures"] > before
    assert stats["platforms"] == 2               # both rows persisted


def test_corrupt_db_warns_never_crashes(db_path):
    with open(db_path, "w") as fh:
        fh.write('{"schema": "repro.tunedb/v1", "platforms": [1, 2]}')
    db = TuneDB()
    with pytest.warns(TuneDBWarning, match="unusable"):
        assert db.get("deadbeef00") is None
    # the next put replaces the corrupt file with a clean one
    db.put("deadbeef00", {"ms": 1.0, "mbps": 2.0})
    with open(db_path) as fh:
        doc = json.load(fh)
    assert doc["schema"] == SCHEMA
    db2 = TuneDB()
    assert db2.get("deadbeef00")["mbps"] == 2.0


@pytest.mark.parametrize("garbage", ["not json at all{{{",
                                     '["a", "list"]',
                                     '{"schema": "something/else"}'])
def test_bad_files_all_warn(db_path, garbage):
    with open(db_path, "w") as fh:
        fh.write(garbage)
    with pytest.warns(TuneDBWarning):
        assert TuneDB().get("aa") is None


def test_concurrent_writers_merge_rows(db_path):
    """Two instances writing different fingerprints must not clobber each
    other: put() re-reads the file as its merge base."""
    a, b = TuneDB(), TuneDB()
    a.get("fp_a")                                # load both tables (empty)
    b.get("fp_b")
    a.put("fp_a", {"ms": 1.0, "mbps": 10.0})
    b.put("fp_b", {"ms": 2.0, "mbps": 20.0})     # merge-with-disk keeps fp_a
    c = TuneDB()
    assert c.get("fp_a")["mbps"] == 10.0
    assert c.get("fp_b")["mbps"] == 20.0
    assert c.stats()["entries"] == 2


def test_invalidate_deletes_file(db_path):
    db = TuneDB()
    db.put("fp", {"ms": 1.0, "mbps": 1.0})
    assert os.path.exists(db_path)
    db.invalidate()
    assert not os.path.exists(db_path)
    assert db.get("fp") is None


def test_measured_span_attrs(db_path):
    """plan_decode(measure=True) must put measured-vs-predicted numbers
    on its span: measured_ms/measured_mbps next to the predicted
    vmem_bytes, plus the cache-vs-fresh candidate counts."""
    t = Tracer()
    set_tracer(t)
    try:
        plan_decode(STD_K7, SPEC, tunedb=TuneDB(), **MEASURE_KW)
    finally:
        set_tracer(None)
    (span,) = [r for r in t.spans() if r.name == "plan_decode"]
    at = span.attrs
    assert at["measured_ms"] > 0 and at["measured_mbps"] > 0
    assert at["vmem_bytes"] > 0                  # predicted, still there
    assert at["measure_candidates"] == at["measure_new"] == 1
    assert at["measure_cached"] == 0
    assert at["fingerprint"] == at["analytic_fingerprint"]


def test_measured_choice_among_candidates(db_path):
    """Unpinned measure pass: top-k candidates all land in the DB and the
    returned plan is one of them (highest measured mbps)."""
    db = TuneDB()
    plan = plan_decode(STD_K7, SPEC, tunedb=db, measure=True,
                       measure_reps=1, measure_top_k=2, chunk_frames=4)
    stats = db.stats()
    assert stats["entries"] == 2 and stats["measures"] == 2
    assert db.get(plan.fingerprint()) is not None
