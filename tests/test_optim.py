import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw, constant, warmup_cosine


def test_adamw_matches_manual_reference():
    opt = adamw(constant(0.1), b1=0.9, b2=0.999, eps=1e-8,
                weight_decay=0.0, clip_norm=1e9)
    p = {"w": jnp.array([[1.0, 2.0]], jnp.float32)}
    g = {"w": jnp.array([[0.5, -0.25]], jnp.float32)}
    s = opt.init(p)
    p1, s1, _ = opt.update(g, s, p)
    # manual adam step 1: m=0.1g, v=0.001g^2; mhat=g, vhat=g^2
    # update = g/(|g|+eps) = sign(g) -> p - 0.1*sign(g)
    np.testing.assert_allclose(np.asarray(p1["w"]),
                               [[1.0 - 0.1, 2.0 + 0.1]], rtol=1e-5)


def test_weight_decay_only_on_matrices():
    opt = adamw(constant(0.1), weight_decay=0.1)
    p = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    g = {"w": jnp.zeros((2, 2)), "b": jnp.zeros((2,))}
    p1, _, _ = opt.update(g, opt.init(p), p)
    assert np.all(np.asarray(p1["w"]) < 1.0)      # decayed
    np.testing.assert_array_equal(np.asarray(p1["b"]), 1.0)   # not decayed


def test_clipping():
    opt = adamw(constant(0.1), clip_norm=1.0)
    p = {"w": jnp.zeros((4,), jnp.float32)}
    g = {"w": jnp.full((4,), 100.0)}
    _, _, met = opt.update(g, opt.init(p), p)
    assert float(met["grad_norm"]) == 200.0       # reported pre-clip


def test_warmup_cosine_shape():
    lr = warmup_cosine(1.0, warmup=10, total=110, floor=0.1)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1.0) < 1e-6
    assert float(lr(jnp.int32(60))) < 1.0
    assert abs(float(lr(jnp.int32(110))) - 0.1) < 1e-6


def test_bf16_params_fp32_moments():
    opt = adamw(constant(1e-2))
    p = {"w": jnp.ones((3, 3), jnp.bfloat16)}
    s = opt.init(p)
    assert s["m"]["w"].dtype == jnp.float32
    g = {"w": jnp.ones((3, 3), jnp.bfloat16)}
    p1, s1, _ = opt.update(g, s, p)
    assert p1["w"].dtype == jnp.bfloat16
