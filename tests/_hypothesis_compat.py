"""Property-test shim: real `hypothesis` when installed, fallback otherwise.

The hermetic CI container has no `hypothesis` wheel (and installs are not
allowed), so this module re-exports (given, settings, st) from hypothesis
when available and otherwise degrades ``@given`` to a deterministic
8-example sweep drawn from a seeded numpy Generator. Coverage is thinner
than real hypothesis shrinking/search, but the property suites keep
running everywhere.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False
    import random

    import numpy as np

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class st:  # noqa: N801 — mirrors `strategies as st`
        @staticmethod
        def integers(lo, hi):
            return _Strategy(lambda r: int(r.integers(lo, hi + 1)))

        @staticmethod
        def sampled_from(xs):
            seq = list(xs)
            return _Strategy(lambda r: seq[int(r.integers(0, len(seq)))])

        @staticmethod
        def randoms():
            return _Strategy(
                lambda r: random.Random(int(r.integers(0, 2**32))))

    class settings:  # noqa: N801
        def __init__(self, **_kw):
            pass

        def __call__(self, f):            # decorator form: pass through
            return f

        @staticmethod
        def register_profile(*_a, **_kw):
            pass

        @staticmethod
        def load_profile(*_a, **_kw):
            pass

    def given(*strats):
        # NB: the wrapper must be zero-arg (not functools.wraps) or pytest
        # would resolve the wrapped function's params as fixtures.
        def deco(f):
            def run():
                rng = np.random.default_rng(0xC0FFEE)
                for _ in range(8):
                    f(*[s.draw(rng) for s in strats])
            run.__name__ = f.__name__
            run.__doc__ = f.__doc__
            return run
        return deco
