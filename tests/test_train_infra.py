"""Training-infrastructure tests: loss goes down, checkpoint atomicity +
resume, failure injection, straggler watchdog, grad compression."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM
from repro.distributed.compress import (compressed_grads, init_ef,
                                        make_compressed_train_step)
from repro.models import build_model
from repro.optim import adamw, warmup_cosine, constant
from repro.train import (LoopConfig, make_accum_train_step, make_train_step,
                         train_loop)
from repro.train import checkpoint as ckpt


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3_32b", reduced=True)
    m = build_model(cfg)
    opt = adamw(warmup_cosine(3e-3, 10, 100))
    step = jax.jit(make_train_step(m, opt))
    return cfg, m, opt, step


def _j(b):
    return {k: jnp.asarray(v) for k, v in b.items()}


def test_loss_decreases(setup):
    cfg, m, opt, step = setup
    it = SyntheticLM(cfg, DataConfig(4, 32, mode="learnable"))
    p = m.init(jax.random.PRNGKey(0))
    o = opt.init(p)
    losses = []
    for _ in range(35):
        p, o, met = step(p, o, _j(next(it)))
        losses.append(float(met["loss"]))
    assert losses[-1] < 0.5 * losses[0]


def test_grad_accumulation_matches_big_batch(setup):
    cfg, m, opt, _ = setup
    p = m.init(jax.random.PRNGKey(0))
    o = opt.init(p)
    b = next(SyntheticLM(cfg, DataConfig(8, 32, mode="learnable")))
    big = _j(b)
    micro = {k: v.reshape(4, 2, *v.shape[1:]) for k, v in big.items()}
    p1, _, m1 = jax.jit(make_train_step(m, opt))(p, o, big)
    p2, _, m2 = jax.jit(make_accum_train_step(m, opt, 4))(p, o, micro)
    # losses match to bf16-accumulation tolerance
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-2
    l1 = jax.tree.leaves(p1)[0].astype(jnp.float32)
    l2 = jax.tree.leaves(p2)[0].astype(jnp.float32)
    assert np.allclose(np.asarray(l1), np.asarray(l2), atol=3e-2)


def test_checkpoint_roundtrip(setup, tmp_path):
    cfg, m, opt, _ = setup
    p = m.init(jax.random.PRNGKey(0))
    state = {"params": p, "opt": opt.init(p)}
    ckpt.save(str(tmp_path), 7, state)
    assert ckpt.latest_step(str(tmp_path)) == 7
    restored = ckpt.restore(str(tmp_path), 7, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_gc_and_torn_write(tmp_path):
    state = {"x": jnp.arange(4)}
    for s in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), s, state, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 4
    assert sorted(ckpt._all_steps(str(tmp_path))) == [3, 4]
    # a torn (incomplete) checkpoint is never selected
    os.makedirs(tmp_path / "step_00000009")
    assert ckpt.latest_step(str(tmp_path)) == 4


def test_failure_recovery_and_resume(setup, tmp_path):
    cfg, m, opt, step = setup
    p = m.init(jax.random.PRNGKey(0))
    state = {"params": p, "opt": opt.init(p)}
    fails = {7}

    def inj(s):
        if s in fails:
            fails.discard(s)
            raise RuntimeError("simulated node failure")

    lc = LoopConfig(total_steps=12, ckpt_dir=str(tmp_path), ckpt_every=4)
    stats = train_loop(
        lambda p, o, b: step(p, o, _j(b)), state,
        SyntheticLM(cfg, DataConfig(4, 32, mode="learnable")), lc,
        fail_injector=inj)
    assert stats.restores == 1
    assert ckpt.latest_step(str(tmp_path)) == 11
    # a fresh loop resumes where the last one stopped
    p = m.init(jax.random.PRNGKey(0))
    state2 = {"params": p, "opt": opt.init(p)}
    lc2 = LoopConfig(total_steps=16, ckpt_dir=str(tmp_path), ckpt_every=4)
    stats2 = train_loop(lambda p, o, b: step(p, o, _j(b)), state2,
                        SyntheticLM(cfg, DataConfig(4, 32, mode="learnable")),
                        lc2)
    assert stats2.steps_run == 4


def test_straggler_watchdog(setup, tmp_path):
    cfg, m, opt, step = setup
    p = m.init(jax.random.PRNGKey(0))
    state = {"params": p, "opt": opt.init(p)}
    flagged = []
    import time as _t
    slow = {6}

    def inj(s):
        if s in slow:
            slow.discard(s)
            _t.sleep(1.0)          # straggle vs ~fast EMA

    lc = LoopConfig(total_steps=8, ckpt_dir=str(tmp_path), ckpt_every=100,
                    straggler_factor=3.0)
    stats = train_loop(lambda p, o, b: step(p, o, _j(b)), state,
                       SyntheticLM(cfg, DataConfig(4, 32)), lc,
                       fail_injector=inj,
                       on_straggler=lambda s, r: flagged.append((s, r)))
    assert stats.stragglers >= 1 and flagged


def test_async_checkpointer(setup, tmp_path):
    cfg, m, opt, _ = setup
    p = m.init(jax.random.PRNGKey(0))
    c = ckpt.Checkpointer(str(tmp_path))
    c.save_async(3, {"params": p})
    c.wait()
    assert ckpt.latest_step(str(tmp_path)) == 3


# ------------------------------------------------------------ compression --
def test_compression_error_feedback():
    """Quantization residual is carried: a constant gradient stream sums
    correctly over steps despite int8 rounding."""
    g = {"w": jnp.full((64,), 0.001234, jnp.float32)}
    ef = init_ef(g)
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()), ("data",))
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    total = np.zeros(64, np.float32)
    for _ in range(50):
        def f(ef_leaf):
            gh, newef = compressed_grads(g, {"w": ef_leaf}, "data")
            return gh["w"], newef["w"]
        fm = shard_map(f, mesh=mesh, in_specs=(P(),), out_specs=(P(), P()),
                       check_rep=False)
        gh, newef = fm(ef["w"])
        ef = {"w": newef}
        total += np.asarray(gh)
    np.testing.assert_allclose(total, 50 * 0.001234, rtol=2e-2)


def test_compressed_train_step_runs():
    cfg = get_config("qwen3_32b", reduced=True)
    m = build_model(cfg)
    opt = adamw(constant(1e-3))
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()), ("data",))
    p = m.init(jax.random.PRNGKey(0))
    st = make_compressed_train_step(m.loss, opt, mesh)
    b = _j(next(SyntheticLM(cfg, DataConfig(2, 16))))
    p2, o2, ef2, met = st(p, opt.init(p), init_ef(p), b)
    assert bool(jnp.isfinite(met["loss"]))
