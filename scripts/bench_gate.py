#!/usr/bin/env python
"""Benchmark-regression gate (scripts/ci.sh).

Runs the interpret-mode kernel sweep + streaming bench + multi-tenant
serve bench + tile-plan report, APPENDS the run to BENCH_kernels.json
(keeping the per-PR trajectory), and fails when the best kernel
configuration OR the serve aggregate throughput regresses more than
``BENCH_GATE_TOL`` (default 20%) against the best comparable run already
stored. Timing is min-of-reps, which absorbs most shared-runner noise; the
tolerance absorbs the rest.

  PYTHONPATH=src python scripts/bench_gate.py

Env knobs: BENCH_GATE_TOL=0.2 (fractional regression allowed),
BENCH_PATH=BENCH_kernels.json.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main() -> int:
    from benchmarks import throughput
    from benchmarks.trajectory import (DEFAULT_PATH, append_run, best_mbps,
                                       load_runs, serve_mbps)

    tol = float(os.environ.get("BENCH_GATE_TOL", "0.2"))
    path = os.environ.get("BENCH_PATH", DEFAULT_PATH)

    rows = throughput.kernel_sweep(full=False)
    stream_rows = throughput.streaming_bench(full=False)
    serve_rows = throughput.serve_bench(full=False)
    plans = throughput.plan_rows()
    run = {"full": False, "rows": rows, "streaming": stream_rows,
           "serve": serve_rows, "plans": plans, "gate": True}
    cur = best_mbps(run)
    n_bits = rows[0]["n_bits"]

    prior = load_runs(path)
    # only compare runs of the same workload size (full flag + n_bits)
    comparable = [r for r in prior
                  if not r.get("full")
                  and all(row.get("n_bits") == n_bits
                          for row in r.get("rows", []))]
    append_run(run, path)

    single = next(r for r in stream_rows if r["variant"] == "single_shot")
    beststream = max((r["mbps"] for r in stream_rows
                      if r["variant"] != "single_shot"), default=0.0)
    print(f"bench gate: best kernel config {cur:.2f} Mb/s; streaming best "
          f"{beststream:.2f} vs single-shot {single['mbps']:.2f} Mb/s")

    # serve section: aggregate server throughput vs the N-independent
    # baseline of THIS run, and vs stored server runs of the same workload
    srv = serve_mbps(run)
    indep = serve_mbps(run, "independent")
    srow = next(r for r in serve_rows if r["variant"] == "server")
    print(f"bench gate: serve {srow['sessions']} sessions/"
          f"{srow['buckets']} buckets — server {srv:.2f} Mb/s vs "
          f"independent {indep:.2f} Mb/s (occupancy "
          f"{srow['occupancy']:.2f}, p99 {srow['p99_ms']:.1f} ms, "
          f"{srow['plan_traces']} compiles)")
    if srv < indep:
        print("bench gate: WARNING — server below summed independent "
              "StreamDecoders this run (runner noise?); see the stored "
              "trajectory for the trend")
    fail = []
    serve_comp = [serve_mbps(r) for r in comparable
                  if any(row.get("variant") == "server"
                         and row.get("sessions") == srow["sessions"]
                         and row.get("n_bits") == srow["n_bits"]
                         for row in r.get("serve", []))]
    if serve_comp:
        sbase = max(serve_comp)
        print(f"bench gate: stored serve baseline {sbase:.2f} Mb/s "
              f"(floor {(1 - tol) * sbase:.2f})")
        if srv < (1.0 - tol) * sbase:
            fail.append(f"serve aggregate regressed "
                        f"{(1 - srv / sbase):.0%} (> {tol:.0%})")
    else:
        print("bench gate: no comparable stored serve baseline — "
              "recorded only")

    if not comparable:
        print("bench gate: no comparable stored baseline — recorded only")
        return 1 if fail else 0
    base = max(best_mbps(r) for r in comparable)
    floor = (1.0 - tol) * base
    print(f"bench gate: stored baseline best {base:.2f} Mb/s "
          f"(floor {floor:.2f}, tol {tol:.0%})")
    if cur < floor:
        fail.append(f"best kernel config regressed "
                    f"{(1 - cur / base):.0%} (> {tol:.0%})")
    for msg in fail:
        print(f"bench gate: FAIL — {msg} vs stored baseline")
    if fail:
        return 1
    print("bench gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
