#!/usr/bin/env python
"""Benchmark-regression gate (scripts/ci.sh).

Runs the kernel sweep + streaming bench + multi-tenant serve bench +
serve-under-faults bench + block-parallel bench + serve load sweep +
tile-plan report, APPENDS the run to BENCH_kernels.json (keeping the
per-PR trajectory), and fails when the best kernel configuration OR the
serve aggregate throughput (clean or under fault injection) OR the
block-parallel throughput regresses more than ``BENCH_GATE_TOL``
(default 20%) against the best comparable run already stored — or when
any serve-load level's p99 latency rises more than the same tolerance
above the best stored p99 (latency gates are inverted: up is bad). Runs
are stamped with the producing platform (trajectory.platform) and only
compared against stored runs of the SAME backend/device kind, so the
interpret-CPU trajectory and any compiled-hardware trajectory gate
independently in one store; ``BENCH_COMPILED=1`` runs the same sections
with compiled kernels on the real backend (exit 0 + notice when the
machine only has a CPU). Timing is min-of-reps, which absorbs most
shared-runner noise; the tolerance absorbs the rest.

  PYTHONPATH=src python scripts/bench_gate.py

Failure modes are explicit, never tracebacks: a corrupt/unreadable
trajectory file, or a benchmark returning an empty/missing section,
prints ``bench gate: ERROR — ...`` and exits 2 (distinct from exit 1 =
a real regression). A missing BENCH_kernels.json is NOT an error — the
run is recorded as the first baseline.

Env knobs: BENCH_GATE_TOL=0.2 (fractional regression allowed),
BENCH_PATH=BENCH_kernels.json, BENCH_COMPILED=1 (compiled-mode gate),
BENCH_PLATFORM=gpu|tpu (force the compiled backend).
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


class GateError(Exception):
    """The gate cannot run (bad trajectory file / empty bench section) —
    reported as 'bench gate: ERROR — ...' + exit 2, never a traceback."""


def _load_prior(path: str) -> list[dict]:
    """Stored trajectory runs; [] when the file does not exist yet (first
    run on a fresh checkout is a baseline-recording run, not an error).
    A file that EXISTS but cannot be parsed is an error — silently
    dropping history would let a regression gate itself green."""
    from benchmarks.trajectory import load_runs
    if not os.path.exists(path):
        print(f"bench gate: no trajectory file at {path} — this run "
              f"becomes the first baseline")
        return []
    try:
        runs = load_runs(path)
    except (OSError, ValueError, KeyError, TypeError, AttributeError) as e:
        raise GateError(
            f"trajectory file {path} exists but cannot be read "
            f"({e.__class__.__name__}: {e}); fix or delete it, or point "
            f"BENCH_PATH elsewhere") from None
    if not isinstance(runs, list):
        raise GateError(f"trajectory file {path} parsed to "
                        f"{type(runs).__name__}, expected a list of runs")
    if not runs:
        # a present-but-empty store (fresh {}, empty v2 envelope, bare
        # []) is a first run, same as a missing file — no baseline to
        # gate against, this run records one
        print(f"bench gate: trajectory at {path} holds no prior runs — "
              f"no baseline, recording only")
    return runs


def _section(run: dict, name: str, required_variant: str | None = None):
    """A run section that the gate is about to index into; empty or
    variant-less sections become a clear GateError instead of an
    IndexError/StopIteration."""
    rows = run.get(name)
    if not rows:
        raise GateError(
            f"benchmark produced no '{name}' rows — the {name} bench "
            f"returned empty; the gate cannot compare this run")
    if required_variant is not None:
        row = next((r for r in rows if r.get("variant") == required_variant),
                   None)
        if row is None:
            raise GateError(
                f"'{name}' section has no '{required_variant}' variant row "
                f"(got {sorted({r.get('variant') for r in rows})})")
        return row
    return rows


#: Platform a run without a recorded platform stamp is assumed to be from:
#: every pre-stamp trajectory point was produced by interpret-mode CPU runs.
_LEGACY_PLATFORM = {"backend": "cpu", "device_kind": "cpu"}


def _run_platform(run: dict) -> dict:
    p = run.get("platform") or _LEGACY_PLATFORM
    return {"backend": p.get("backend"), "device_kind": p.get("device_kind")}


def comparable_runs(prior: list[dict], cur_plat: dict,
                    n_bits: int) -> list[dict]:
    """Stored runs the current run may be gated against: same quick (not
    --full) workload with the same kernel-sweep n_bits, AND the same
    platform (backend + device kind) — an interpret-CPU point must never
    be compared to a compiled-GPU/TPU point of the same code (orders of
    magnitude apart), so each platform's trajectory gates independently
    inside one store. Runs without a platform stamp predate the stamp and
    were all produced by interpret-CPU runs (_LEGACY_PLATFORM)."""
    return [r for r in prior
            if not r.get("full")
            and _run_platform(r) == cur_plat
            and all(row.get("n_bits") == n_bits
                    for row in r.get("rows", []))]


def main() -> int:
    from benchmarks.trajectory import (DEFAULT_PATH, append_run, best_mbps,
                                       block_mbps, platform, serve_load_p99,
                                       serve_mbps, serve_under_faults_mbps)

    tol = float(os.environ.get("BENCH_GATE_TOL", "0.2"))
    path = os.environ.get("BENCH_PATH", DEFAULT_PATH)

    prior = _load_prior(path)                  # fail fast, BEFORE the
                                               # heavy imports and the
                                               # minutes-long benches run
    from benchmarks import throughput

    if os.environ.get("BENCH_COMPILED"):
        # compiled-mode gate: same sections, kernels compiled for the real
        # backend; the platform stamp keeps this trajectory separate from
        # the interpret-CPU one, so both gate independently in one store
        from benchmarks import compiled
        backend = compiled.set_platform(os.environ.get("BENCH_PLATFORM"))
        if backend == "cpu":
            print("bench gate: BENCH_COMPILED set but no accelerator is "
                  "available — compiled gate skipped (the interpret-CPU "
                  "gate is the default run)")
            return 0
        throughput.set_compiled(True)
        print(f"bench gate: compiled mode on backend {backend!r}")

    section_s: dict[str, float] = {}

    def timed(name, fn):
        """Run one bench section, keeping its wall time — the recorded
        trajectory then shows where the gate's minutes actually go (and
        when a PR makes one section balloon)."""
        t0 = time.perf_counter()
        out = fn()
        section_s[name] = round(time.perf_counter() - t0, 3)
        return out

    rows = timed("kernels", lambda: throughput.kernel_sweep(full=False))
    stream_rows = timed("streaming",
                        lambda: throughput.streaming_bench(full=False))
    serve_rows = timed("serve", lambda: throughput.serve_bench(full=False))
    faults_rows = timed("serve_faults",
                        lambda: throughput.serve_faults_bench(full=False))
    block_rows = timed("block", lambda: throughput.block_bench(full=False))
    load_rows = timed("serve_load",
                      lambda: throughput.serve_load_sweep(full=False))
    plans = timed("plans", throughput.plan_rows)
    run = {"full": False, "rows": rows, "streaming": stream_rows,
           "serve": serve_rows, "serve_faults": faults_rows,
           "block": block_rows, "serve_load": load_rows, "plans": plans,
           "section_s": section_s, "gate": True}
    if not rows:
        raise GateError("kernel_sweep returned no rows — nothing to gate")
    cur = best_mbps(run)
    n_bits = rows[0]["n_bits"]

    # only compare runs of the same workload size (full flag + n_bits) AND
    # the same platform (backend + device kind): an interpret-CPU point
    # must never be gated against a compiled/TPU point — same code, orders
    # of magnitude apart (pre-stamp legacy runs were all interpret-CPU)
    cur_plat = _run_platform({"platform": platform()})
    comparable = comparable_runs(prior, cur_plat, n_bits)
    skipped_plat = sum(1 for r in prior if _run_platform(r) != cur_plat)
    if skipped_plat:
        print(f"bench gate: ignoring {skipped_plat} stored run(s) from a "
              f"different platform (this run: {cur_plat})")
    append_run(run, path)

    print("bench gate: section wall time — "
          + ", ".join(f"{k} {v:.1f}s" for k, v in section_s.items()))
    single = _section(run, "streaming", "single_shot")
    beststream = max((r["mbps"] for r in stream_rows
                      if r["variant"] != "single_shot"), default=0.0)
    print(f"bench gate: best kernel config {cur:.2f} Mb/s; streaming best "
          f"{beststream:.2f} vs single-shot {single['mbps']:.2f} Mb/s")

    # serve section: aggregate server throughput vs the N-independent
    # baseline of THIS run, and vs stored server runs of the same workload
    srv = serve_mbps(run)
    indep = serve_mbps(run, "independent")
    srow = _section(run, "serve", "server")
    print(f"bench gate: serve {srow['sessions']} sessions/"
          f"{srow['buckets']} buckets — server {srv:.2f} Mb/s vs "
          f"independent {indep:.2f} Mb/s (occupancy "
          f"{srow['occupancy']:.2f}, p99 {srow['p99_ms']:.1f} ms, "
          f"{srow['plan_traces']} compiles)")
    if srv < indep:
        print("bench gate: WARNING — server below summed independent "
              "StreamDecoders this run (runner noise?); see the stored "
              "trajectory for the trend")
    fail = []
    serve_comp = [serve_mbps(r) for r in comparable
                  if any(row.get("variant") == "server"
                         and row.get("sessions") == srow["sessions"]
                         and row.get("n_bits") == srow["n_bits"]
                         for row in r.get("serve", []))]
    if serve_comp:
        sbase = max(serve_comp)
        print(f"bench gate: stored serve baseline {sbase:.2f} Mb/s "
              f"(floor {(1 - tol) * sbase:.2f})")
        if srv < (1.0 - tol) * sbase:
            fail.append(f"serve aggregate regressed "
                        f"{(1 - srv / sbase):.0%} (> {tol:.0%})")
    else:
        print("bench gate: no comparable stored serve baseline — "
              "recorded only")

    # serve-under-faults section: the same comparison for the workload
    # with the seeded 1%-launch-failure injection — catches a fault-
    # tolerance layer whose recovery path got expensive
    frow = _section(run, "serve_faults", "server_faults")
    fsrv = serve_under_faults_mbps(run)
    print(f"bench gate: serve under faults {fsrv:.2f} Mb/s "
          f"({frow['injected']} injected launch failures, "
          f"{frow['retries']} retries, {frow['degraded']} degraded, "
          f"health={frow['health']})")
    faults_comp = [serve_under_faults_mbps(r) for r in comparable
                   if any(row.get("variant") == "server_faults"
                          and row.get("sessions") == frow["sessions"]
                          and row.get("n_bits") == frow["n_bits"]
                          for row in r.get("serve_faults", []))]
    if faults_comp:
        fbase = max(faults_comp)
        print(f"bench gate: stored serve-under-faults baseline "
              f"{fbase:.2f} Mb/s (floor {(1 - tol) * fbase:.2f})")
        if fsrv < (1.0 - tol) * fbase:
            fail.append(f"serve-under-faults aggregate regressed "
                        f"{(1 - fsrv / fbase):.0%} (> {tol:.0%})")
    else:
        print("bench gate: no comparable stored serve-under-faults "
              "baseline — recorded only")

    # block section: intra-frame block-parallel vs sequential-scan plan on
    # the long-frame workload; block_bench already asserts the >= 1.5x
    # acceptance ratio, the gate additionally tracks the blocked Mb/s
    # trajectory like the serve sections
    brow = _section(run, "block", "blocked")
    blk = block_mbps(run)
    seq = block_mbps(run, "sequential")
    print(f"bench gate: block f={brow['f']} x{brow['block_frames']} "
          f"(overlap {brow['overlap']}) — blocked {blk:.2f} Mb/s vs "
          f"sequential {seq:.2f} Mb/s ({blk / seq:.1f}x)")
    block_comp = [block_mbps(r) for r in comparable
                  if any(row.get("variant") == "blocked"
                         and row.get("n_bits") == brow["n_bits"]
                         for row in r.get("block", []))]
    if block_comp:
        bbase = max(block_comp)
        print(f"bench gate: stored block baseline {bbase:.2f} Mb/s "
              f"(floor {(1 - tol) * bbase:.2f})")
        if blk < (1.0 - tol) * bbase:
            fail.append(f"block-parallel throughput regressed "
                        f"{(1 - blk / bbase):.0%} (> {tol:.0%})")
    else:
        print("bench gate: no comparable stored block baseline — "
              "recorded only")

    # serve_load section: tail-latency-under-load SLO curves. INVERTED
    # semantics vs every section above — p99 latency regresses UP, so each
    # offered-load level fails when its p99 exceeds (1 + tol) x the best
    # (minimum) stored comparable p99 at that level
    lrows = _section(run, "serve_load")
    print("bench gate: serve load sweep — "
          + ", ".join(f"{r['sessions']} sess: p99 {r['p99_ms']:.1f} ms "
                      f"(queue {r['queue_p99_ms']:.1f})" for r in lrows))
    for lrow in lrows:
        lvl, cur_p99 = lrow["sessions"], lrow["p99_ms"]
        load_comp = [serve_load_p99(r, lvl) for r in comparable
                     if any(row.get("sessions") == lvl
                            and row.get("n_bits") == lrow["n_bits"]
                            for row in r.get("serve_load", []))]
        load_comp = [p for p in load_comp if p > 0]
        if not load_comp:
            print(f"bench gate: no stored serve-load baseline at {lvl} "
                  f"sessions — recorded only")
            continue
        lbase = min(load_comp)
        ceil = (1.0 + tol) * lbase
        print(f"bench gate: stored serve-load p99 baseline at {lvl} "
              f"sessions {lbase:.1f} ms (ceiling {ceil:.1f})")
        if cur_p99 > ceil:
            fail.append(f"serve p99 at {lvl} sessions regressed "
                        f"{(cur_p99 / lbase - 1):.0%} (> {tol:.0%})")

    if not comparable:
        print("bench gate: no comparable stored baseline — recorded only")
        return 1 if fail else 0
    base = max(best_mbps(r) for r in comparable)
    floor = (1.0 - tol) * base
    print(f"bench gate: stored baseline best {base:.2f} Mb/s "
          f"(floor {floor:.2f}, tol {tol:.0%})")
    if cur < floor:
        fail.append(f"best kernel config regressed "
                    f"{(1 - cur / base):.0%} (> {tol:.0%})")
    for msg in fail:
        print(f"bench gate: FAIL — {msg} vs stored baseline")
    if fail:
        return 1
    print("bench gate: OK")
    return 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except GateError as e:
        print(f"bench gate: ERROR — {e}")
        sys.exit(2)
