#!/usr/bin/env bash
# CI gate: lint + tier-1 test suite + a ~30 s interpret-mode kernel smoke
# bench + a multi-tenant serve smoke + a traced-serve observability smoke
# + the benchmark-regression gate.
#
#   bash scripts/ci.sh           # what .github/workflows/ci.yml runs
#
# The smoke bench decodes real noisy frames with the seed kernel config and
# the optimized one (packed survivors, radix-4, autotuned tiles), asserts
# they are bit-identical to the pure-JAX oracle, and fails if the optimized
# path regresses to slower than the seed path. scripts/bench_gate.py then
# runs the full sweep, APPENDS it to BENCH_kernels.json (per-PR trajectory)
# and fails on a >20% regression of the best config vs the stored baseline.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

# Observability artifacts (Perfetto traces, metrics expositions, the bench
# run appended this CI pass) land here; the workflow uploads the directory
# even on failure so a red run still ships its evidence.
ARTIFACTS="${CI_ARTIFACTS:-/tmp/ci_artifacts}"
mkdir -p "$ARTIFACTS"

# ---- lint: a bare fori_loop/scan/while_loop at statement level discards
# its carry — inside Pallas kernels the loop only survives because of ref-
# write effects, and a DCE change would silently drop it (the radix-2
# traceback did exactly this until PR 4). Assign the result.
if grep -RnE '^[[:space:]]*(jax\.)?lax\.(fori_loop|while_loop|scan)\(' \
        src benchmarks examples; then
    echo "LINT: unused loop result (assign the carry of fori_loop/scan)" >&2
    exit 1
fi

python -m pytest -x -q

python - <<'EOF'
import time
import numpy as np
import jax, jax.numpy as jnp
from repro.core import FrameSpec, STD_K7
from repro.core.framed import frame_llr
from repro.kernels import ops, ref

rng = np.random.default_rng(0)
spec = FrameSpec(f=256, v1=20, v2=45, f0=32, v2s=45)
llr = jnp.asarray(rng.standard_normal((16 * spec.f, 2)).astype(np.float32))
frames = frame_llr(llr, spec)
want = np.asarray(ref.unified_decode_frames_ref(frames, STD_K7, spec))

def bench(label, **kw):
    fn = jax.jit(lambda fr: ops.viterbi_decode_frames(
        fr, STD_K7, spec, interpret=True, **kw))
    out = fn(frames)
    out.block_until_ready()                        # compile + warm
    assert np.array_equal(np.asarray(out), want), f"{label}: WRONG BITS"
    reps = []                                      # best-of-3: shared CI
    for _ in range(3):                             # runners are noisy
        t0 = time.perf_counter()
        fn(frames).block_until_ready()
        reps.append(time.perf_counter() - t0)
    dt = min(reps)
    print(f"smoke {label}: {dt*1e3:.1f} ms  (bit-exact)")
    return dt

seed = bench("seed    (unpacked, radix-2, ft=8, lane)",
             pack_survivors=False, radix=2, frames_per_tile=8, layout="lane")
opt = bench("optimized (packed, radix-4, auto, sublane)",
            pack_survivors=True, radix=4, frames_per_tile="auto",
            layout="sublane")
# bit-exactness above is the hard gate; shared-runner wall clock is too
# noisy (seed config varies ~1.7x run-to-run) for a tight perf assert, so
# only fail on a gross regression and warn otherwise.
if opt >= seed:
    print(f"WARNING: optimized path not faster this run "
          f"({opt*1e3:.1f} ms vs {seed*1e3:.1f} ms) — likely runner noise; "
          f"see BENCH_kernels.json for the multi-config sweep")
assert opt < 3.0 * seed, f"gross perf regression: {opt:.3f}s vs {seed:.3f}s"
print("SMOKE_OK")
EOF

# ---- block smoke: the intra-frame block-parallel decode's two exactness
# gates. (a) degenerate: when overlap covers the whole frame, the blocked
# kernel decode must be BIT-IDENTICAL to the unblocked one; (b) long-frame
# BER: blocking a f=4096 stream with the auto policy (~5K overlap) must
# stay within 1e-3 BER of the sequential exact decode at the gated SNR.
python - <<'EOF'
import numpy as np
import jax, jax.numpy as jnp
from repro.core import DecoderConfig, FrameSpec, STD_K7, encode, make_decoder
from repro.core.framed import frame_llr
from repro.channel.sim import awgn, bpsk
from repro.kernels import ops
from repro.kernels.block import full_overlap, resolve_block

rng = np.random.default_rng(0)

# (a) degenerate full-overlap bit-identity on the kernel path
spec = FrameSpec(f=64, v1=16, v2=20)
llr = jnp.asarray(rng.standard_normal((8 * spec.f, 2)).astype(np.float32))
frames = frame_llr(llr, spec)
B = 4
ov = full_overlap(spec, B)
plain = ops.viterbi_decode_frames(frames, STD_K7, spec)
blocked = ops.viterbi_decode_frames(frames, STD_K7, spec,
                                    block_frames=B, overlap=ov)
assert np.array_equal(np.asarray(plain), np.asarray(blocked)), \
    "degenerate full-overlap blocking is NOT bit-identical"

# (b) long-frame BER gate: auto blocking vs sequential exact decode
spec_l = FrameSpec(f=4096, v1=32, v2=32, f0=32, v2s=32)
bf, ovr = resolve_block(STD_K7, spec_l, "auto", None)
assert bf > 1, f"auto policy did not engage at f={spec_l.f}"
n = 8 * spec_l.f
bits = jnp.asarray(rng.integers(0, 2, n))
tx = bpsk(encode(bits, STD_K7).reshape(-1))
rx = jnp.asarray(np.asarray(
    awgn(jax.random.PRNGKey(3), tx, 2.0)).reshape(n, 2))
seq = make_decoder(DecoderConfig(spec=spec_l))
blk = make_decoder(DecoderConfig(spec=spec_l, block_frames="auto"))
want = np.asarray(bits)
ber_seq = float(np.mean(np.asarray(seq(rx, n)) != want))
ber_blk = float(np.mean(np.asarray(blk(rx, n)) != want))
assert abs(ber_blk - ber_seq) < 1e-3, \
    f"block BER gate: |{ber_blk:.2e} - {ber_seq:.2e}| >= 1e-3"
print(f"block smoke: degenerate x{B} (overlap {ov}) bit-exact; "
      f"f={spec_l.f} auto -> x{bf} (overlap {ovr}), "
      f"BER {ber_blk:.2e} vs sequential {ber_seq:.2e} @ 2 dB")
print("BLOCK_SMOKE_OK")
EOF

# ---- serve smoke: 8 concurrent sessions across 3 code configs through
# the multi-tenant DecodeServer must be bit-identical to each session's
# solo stream_decode, with one plan-cache trace per bucket shape.
python - <<'EOF'
import numpy as np
import jax, jax.numpy as jnp
from repro.core import DecoderConfig, FrameSpec, STD_K7, encode
from repro.core.puncture import puncture
from repro.core.stream import stream_decode
from repro.core.trellis import make_trellis
from repro.channel.sim import awgn, bpsk
from repro.serve import DecodeServer, PlanCache

spec12 = FrameSpec(f=64, v1=16, v2=20, f0=16, v2s=20)
spec34 = FrameSpec(f=63, v1=21, v2=21, f0=21, v2s=21)
cfgs = [DecoderConfig(spec=spec12),
        DecoderConfig(spec=spec34, rate="3/4"),
        DecoderConfig(trellis=make_trellis(5, (0o23, 0o35)), spec=spec12)]
rng = np.random.default_rng(0)

def rx_for(cfg, n, seed):
    bits = jnp.asarray(rng.integers(0, 2, n))
    coded = encode(bits, cfg.trellis)
    tx = bpsk(puncture(coded, cfg.rate)) if cfg.rate != "1/2" \
        else bpsk(coded.reshape(-1))
    r = np.asarray(awgn(jax.random.PRNGKey(seed), tx, 4.0))
    return r if cfg.rate != "1/2" else r.reshape(n, 2)

cache = PlanCache()
srv = DecodeServer(slots=3, cache=cache)
tenants = []
for i in range(8):
    cfg = cfgs[i % 3]
    n = 4 * 5 * cfg.spec.f
    rx = rx_for(cfg, n, i)
    tenants.append((srv.open_session(cfg, chunk_frames=5), cfg, rx, n))
for r in range(4):
    for sid, cfg, rx, n in tenants:
        per = rx.shape[0] // 4
        srv.push(sid, rx[r * per:(r + 1) * per])
    while srv.step():
        pass
for sid, cfg, rx, n in tenants:
    got = np.concatenate([srv.poll(sid), srv.close_session(sid)])[:n]
    want = stream_decode(cfg, rx, n, chunk_frames=5)
    assert np.array_equal(got, want), f"serve session {sid}: WRONG BITS"
stats = cache.stats()
assert stats["traces"] <= 2 * 3, stats   # <=2 batch shapes per bucket
assert stats["hits"] > stats["misses"], stats
print(f"serve smoke: 8 sessions / {len(srv.buckets())} buckets bit-exact, "
      f"plan cache {stats}")
print("SERVE_SMOKE_OK")
EOF

# ---- chaos smoke: the same multi-tenant service under a seeded fault
# schedule — injected kernel-launch failures, slow launches past the
# per-launch deadline, forced plan-cache evictions, and one tenant
# pushing NaN-poisoned LLRs. Healthy sessions must come out bit-identical
# to their solo stream_decode; the poisoned tenant must be quarantined
# with structured errors (teardown still works); the server loop must
# never die; every fault must show up in metrics_snapshot().
python - <<'EOF'
import numpy as np
import jax, jax.numpy as jnp
from repro.core import DecoderConfig, FrameSpec, encode
from repro.core.stream import stream_decode
from repro.channel.sim import awgn, bpsk
from repro.serve import DecodeServer, PlanCache, SessionQuarantined
from repro.testing import FaultInjector, FaultSpec

spec = FrameSpec(f=64, v1=16, v2=20, f0=16, v2s=20)
cfg = DecoderConfig(spec=spec)
rng = np.random.default_rng(0)

def rx_for(n, seed):
    bits = jnp.asarray(rng.integers(0, 2, n))
    tx = bpsk(encode(bits, cfg.trellis).reshape(-1))
    return np.asarray(awgn(jax.random.PRNGKey(seed), tx, 4.0)).reshape(n, 2)

nround, n = 4, 4 * 5 * spec.f
rx = [rx_for(n, i) for i in range(4)]
faults = FaultInjector(
    FaultSpec("launch_error", every=3),
    FaultSpec("launch_slow", every=4, delay_s=0.08),
    FaultSpec("corrupt_llr", every=2, mode="nan", sessions=(3,)),
    FaultSpec("plan_cache_miss", every=5),
    seed=5)
srv = DecodeServer(slots=4, cache=PlanCache(), faults=faults,
                   launch_timeout_s=0.04, max_retries=2, backoff_s=0.0,
                   quarantine_after=2)
sids = [srv.open_session(cfg, chunk_frames=5) for _ in range(4)]
refused = 0
per = n // nround
for r in range(nround):
    for sid in sids:
        try:
            srv.push(sid, rx[sid][r * per:(r + 1) * per])
        except SessionQuarantined as e:
            assert (e.sid, e.retry_after_steps) == (3, None), e
            refused += 1
    while srv.step():                       # the loop must survive faults
        pass
assert refused >= 1, "poisoned tenant was never quarantined"
try:
    srv.poll(3)
    raise AssertionError("poll of a quarantined session did not raise")
except SessionQuarantined as e:
    assert e.strikes >= 2 and "quarantined" in str(e), e

snap = srv.metrics_snapshot()
tot = snap["totals"]
assert snap["quarantined_sessions"] == 1, snap
assert tot["launch_errors"] > 0 and tot["timeouts"] > 0, tot
assert tot["poisoned_pushes"] >= 2 and tot["sanitized_values"] > 0, tot
assert tot["quarantined"] == 1 and tot["cache_refreshes"] >= 1, tot
assert tot["health"] in ("impaired", "degraded"), tot
assert snap["faults"]["injected"]["launch_error"] >= 1, snap["faults"]

for sid in (0, 1, 2):                       # healthy tenants: bit-exact
    got = np.concatenate([srv.poll(sid), srv.close_session(sid)])[:n]
    want = stream_decode(cfg, rx[sid], n, chunk_frames=5)
    assert np.array_equal(got, want), f"healthy session {sid}: WRONG BITS"
qbits = srv.close_session(3)                # teardown always works
assert srv.num_sessions == 0
print(f"chaos smoke: {tot['launch_errors']} launch errors, "
      f"{tot['timeouts']} timeouts, {tot['retries']} retries, "
      f"{tot['degraded']} degraded, {tot['sanitized_values']} LLRs "
      f"sanitized, 1 tenant quarantined ({qbits.size} bits salvaged) — "
      f"3 healthy tenants bit-exact, health={tot['health']}")
print("CHAOS_SMOKE_OK")
EOF

# ---- crash-recovery chaos stage: a seeded crash_at_step kills the
# server mid-workload; a FRESH server restores from the last checkpoint
# and the client replays from its marker. Gates: (a) every session's
# final bits are bit-identical to the uninterrupted solo decode, (b) the
# restored metrics_snapshot() preserves the fault counters and the
# uptime accounting accumulated before the crash, (c) a checkpoint
# corrupted in flight is REJECTED with a structured error — the previous
# good checkpoint (atomic replace) still loads.
python - <<'EOF'
import numpy as np
import jax, jax.numpy as jnp
from repro.core import DecoderConfig, FrameSpec, encode
from repro.core.stream import stream_decode
from repro.channel.sim import awgn, bpsk
from repro.serve import CheckpointError, DecodeServer, PlanCache
from repro.testing import FaultInjector, FaultSpec
from repro.testing.faults import InjectedCrash

spec = FrameSpec(f=64, v1=16, v2=20, f0=16, v2s=20)
cfg = DecoderConfig(spec=spec)
rng = np.random.default_rng(7)

def rx_for(n, seed):
    bits = jnp.asarray(rng.integers(0, 2, n))
    tx = bpsk(encode(bits, cfg.trellis).reshape(-1))
    return np.asarray(awgn(jax.random.PRNGKey(seed), tx, 4.0)).reshape(n, 2)

n = 16 * 64
rx = {k: rx_for(n, k) for k in range(3)}
CK = "/tmp/ci_serve.ckpt"
faults = FaultInjector(FaultSpec("launch_error", every=4),
                       FaultSpec("crash_at_step", after=3, count=1), seed=5)
srv = DecodeServer(slots=4, cache=PlanCache(), max_retries=2,
                   backoff_s=0.0, faults=faults)
sids = {k: srv.open_session(cfg, chunk_frames=2) for k in rx}
pos = {k: 0 for k in rx}
bits = {k: [] for k in rx}
srv.checkpoint(CK)
mark = ({k: 0 for k in rx}, dict(pos))
pre_crash = None
crashes = 0
while any(p < n for p in pos.values()):
    try:
        for k, sid in sids.items():
            if pos[k] < n:
                srv.push(sid, rx[k][pos[k]:pos[k] + 2 * 64])
                pos[k] += 2 * 64
        srv.step()
        for k, sid in sids.items():
            bits[k].append(srv.poll(sid))
        srv.checkpoint(CK)
        pre_crash = srv.metrics_snapshot()   # after the save: counters
        mark = ({k: sum(len(b) for b in bits[k]) for k in rx}, dict(pos))
    except InjectedCrash:
        crashes += 1
        srv = DecodeServer.restore(CK, cache=PlanCache())
        post = srv.metrics_snapshot()
        for c in ("launch_errors", "retries", "launches", "bits"):
            assert post["totals"][c] == pre_crash["totals"][c], c
        # restored uptime resumes from the SAVED clock, which trails the
        # snapshot above by the wall time of one statement — allow 10 ms
        assert post["totals"]["uptime_s"] > 0.0
        assert post["totals"]["uptime_s"] >= \
            pre_crash["totals"]["uptime_s"] - 0.01
        assert post["checkpoint"]["restores"] == 1, post["checkpoint"]
        delivered, posmark = mark
        for k in rx:
            acc = (np.concatenate(bits[k]) if bits[k]
                   else np.zeros(0, np.int32))
            bits[k] = [acc[:delivered[k]]]
        pos = dict(posmark)
assert crashes == 1, "the seeded crash never fired"
for k, sid in sids.items():
    bits[k].append(srv.close_session(sid))
for k in rx:
    got = np.concatenate(bits[k])[:n]
    want = stream_decode(cfg, rx[k], n, chunk_frames=2)
    assert np.array_equal(got, want), \
        f"session {k}: NOT bit-identical after crash+restore"

# torn checkpoint: a file corrupted in flight must be refused outright
faults2 = FaultInjector(FaultSpec("checkpoint_corrupt", after=1), seed=0)
srv2 = DecodeServer(cache=PlanCache(), faults=faults2)
srv2.open_session(cfg, chunk_frames=2)
srv2.checkpoint("/tmp/ci_serve_torn.ckpt")
try:
    DecodeServer.restore("/tmp/ci_serve_torn.ckpt")
    raise AssertionError("corrupt checkpoint was accepted")
except CheckpointError:
    pass
assert DecodeServer.restore(CK, cache=PlanCache()).num_sessions == 3
print(f"crash-recovery smoke: crash at step 3 recovered from {CK}; "
      f"3 sessions bit-identical, counters+uptime preserved across the "
      f"restore, torn checkpoint refused")
print("CRASH_RECOVERY_OK")
EOF

# ---- obs smoke: the chaos workload again, traced end to end. The demo
# must emit a Chrome trace-event file that (a) parses, (b) contains the
# nested push/launch/launch_attempt/retire spans plus the retry/degrade
# recovery markers, and (c) pairs every async begin with an end — i.e.
# the trace a human would load into Perfetto is actually well-formed.
# The same run writes the metrics exposition pair (.prom/.json), which
# must parse as Prometheus text with true histogram series and as strict
# JSON carrying the cumulative stage histograms.
python examples/serve_viterbi.py --sessions 4 --chunks 3 --chaos \
    --trace-out "$ARTIFACTS/obs_trace.json" \
    --metrics-out "$ARTIFACTS/serve_metrics"
python - "$ARTIFACTS" <<'EOF'
import json, re, sys
art = sys.argv[1]
obj = json.load(open(art + "/obs_trace.json"))
ev = obj["traceEvents"]
names = {e["name"] for e in ev}
for want in ("push", "launch", "launch_attempt", "retire", "retry",
             "batch_pack", "plan_build"):
    assert want in names, f"trace missing {want!r} spans: {sorted(names)}"
for e in ev:
    if e["ph"] == "X":
        assert e["ts"] >= 0 and e["dur"] >= 0, e
b = [e["id"] for e in ev if e["ph"] == "b"]
e_ = [e["id"] for e in ev if e["ph"] == "e"]
assert b and sorted(b) == sorted(e_), (len(b), len(e_))
assert obj["otherData"]["counters"]["plan_cache_misses"] > 0
print(f"obs smoke: {len(ev)} events, {len(b)} async pairs, "
      f"spans {sorted(names - {'process_name'})}")

# metrics exposition pair: every .prom line parses, the stage histograms
# are present with cumulative buckets ending at +Inf, and the .json twin
# is strict JSON with the same counts
line_re = re.compile(
    r'^(# TYPE [a-zA-Z_:][a-zA-Z0-9_:]* (counter|gauge|histogram)'
    r'|[a-zA-Z_:][a-zA-Z0-9_:]*(\{[a-zA-Z0-9_]+="[^"]*"'
    r'(,[a-zA-Z0-9_]+="[^"]*")*\})? -?[0-9.e+-]+)$')
prom = open(art + "/serve_metrics.prom").read()
for line in prom.strip().split("\n"):
    assert line_re.match(line), f"unparseable exposition line: {line!r}"
assert "# TYPE repro_serve_stage_ms histogram" in prom
assert 'repro_serve_stage_ms_bucket{le="+Inf",stage="launch_ms"}' in prom
snap = json.load(open(art + "/serve_metrics.json"))
hist = snap["stages_hist"]["launch_ms"]
assert hist["buckets"][-1][0] == "+Inf"
assert hist["buckets"][-1][1] == hist["count"] > 0
print(f"obs smoke: exposition {len(prom.splitlines())} lines, "
      f"{len(snap['stages_hist'])} stage histograms")
print("OBS_SMOKE_OK")
EOF

# ---- compiled-mode smoke: the accelerator bench entry point must run
# cleanly wherever CI lands. On a CPU-only runner it prints the skip
# notice and exits 0; on a machine with a real backend it compiles and
# runs the kernel sweep for real (interpret=False).
python benchmarks/throughput.py --compiled --sections kernels \
    | tee "$ARTIFACTS/compiled_smoke.txt"
echo "COMPILED_SMOKE_OK"

python scripts/bench_gate.py

# ---- archive the trajectory delta: the run bench_gate just appended
# (platform stamp, serve_load SLO rows and all) plus the full trajectory,
# so a reviewer can diff perf without re-running the benches.
python - "$ARTIFACTS" <<'EOF'
import json, sys
runs = json.load(open("BENCH_kernels.json"))["runs"]
with open(sys.argv[1] + "/bench_last_run.json", "w") as fh:
    json.dump(runs[-1], fh, indent=1, sort_keys=True)
    fh.write("\n")
print(f"archived run {len(runs)}/{len(runs)} of the trajectory "
      f"(platform {runs[-1].get('platform', 'pre-stamp')})")
EOF
cp BENCH_kernels.json "$ARTIFACTS/BENCH_kernels.json"
ls -l "$ARTIFACTS"
