"""SDR receiver pipeline: punctured rate-3/4 stream -> depuncture ->
framed decode (parallel traceback) -> BER, plus a sharded multi-device
variant of the same decode (frames are the parallel axis — the paper's
tiling is also the distribution strategy).

PYTHONPATH=src python examples/sdr_pipeline.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import FrameSpec, STD_K7, encode
from repro.core.framed import frame_llr, decode_frame
from repro.core.pipeline import DecoderConfig, make_decoder
from repro.core.puncture import puncture, depuncture
from repro.channel.sim import awgn, ber, bpsk

n = 99_999
rate = "3/4"
rng = np.random.default_rng(0)
bits = jnp.asarray(rng.integers(0, 2, n))

tx = bpsk(puncture(encode(bits, STD_K7), rate))
print(f"tx: {n} info bits -> {tx.shape[0]} channel symbols (rate {rate})")
rx = awgn(jax.random.PRNGKey(1), tx, 6.0)

spec = FrameSpec(f=252, v1=21, v2=45, f0=42, v2s=45)
dec = make_decoder(DecoderConfig(spec=spec, rate=rate))
out = dec(rx, n)
print(f"punctured {rate} BER @ 6 dB: {float(ber(out, bits)):.2e}")

# ---- distributed decode: shard the FRAME axis over every local device ----
mesh = Mesh(np.array(jax.devices()), ("frames",))
llr = depuncture(rx, rate, n)
frames = frame_llr(llr, spec)
fsh = NamedSharding(mesh, P("frames", None, None))


@jax.jit
def decode_sharded(frames):
    return jax.vmap(lambda fr: decode_frame(fr, STD_K7, spec))(frames)


with mesh:
    frames = jax.device_put(frames, fsh)
    t0 = time.perf_counter()
    bits_out = decode_sharded(frames)
    bits_out.block_until_ready()
    dt = time.perf_counter() - t0
out2 = bits_out.reshape(-1)[:n]
print(f"sharded decode over {mesh.devices.size} device(s): "
      f"{n/dt/1e6:.2f} Mb/s, BER {float(ber(out2, bits)):.2e}")
assert jnp.array_equal(out, out2)
