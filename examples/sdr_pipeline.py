"""SDR receiver pipeline: punctured rate-3/4 stream -> depuncture ->
framed decode (parallel traceback) -> BER, plus the STREAMING front-end:
the same stream pushed chunk-by-chunk (as a real receiver would) through
core.stream's double-buffered decoder, frame-sharded over every local
device (the paper's tiling is also the distribution strategy).

All decode paths use DecoderConfig's library defaults (bit-packed
survivors, radix-4 ACS, autotuned tiles for the kernel backends) — no
hand-rolled seed-era knob sets.

PYTHONPATH=src python examples/sdr_pipeline.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FrameSpec, STD_K7, encode
from repro.core.pipeline import DecoderConfig, make_decoder
from repro.core.puncture import puncture, depuncture
from repro.core.stream import make_stream_decoder
from repro.distributed.stream import frame_mesh
from repro.channel.sim import awgn, ber, bpsk

n = 99_999
rate = "3/4"
rng = np.random.default_rng(0)
bits = jnp.asarray(rng.integers(0, 2, n))

tx = bpsk(puncture(encode(bits, STD_K7), rate))
print(f"tx: {n} info bits -> {tx.shape[0]} channel symbols (rate {rate})")
rx = awgn(jax.random.PRNGKey(1), tx, 6.0)

spec = FrameSpec(f=252, v1=21, v2=45, f0=42, v2s=45)
cfg = DecoderConfig(spec=spec, rate=rate)
dec = make_decoder(cfg)
out = dec(rx, n)
print(f"punctured {rate} BER @ 6 dB: {float(ber(out, bits)):.2e}")

# ---- streaming decode, frame-sharded over every local device ------------
# Depuncture once (pattern alignment is stream-global), then push the LLR
# stream in receiver-sized slices; chunks are dispatched asynchronously
# (double-buffered) and each chunk's frames are tiled across the mesh.
mesh = frame_mesh()
llr = np.asarray(depuncture(rx, rate, n))
sdec = make_stream_decoder(cfg, mesh=mesh)
push = 16 * spec.f                                   # stages per push
t0 = time.perf_counter()
parts = [sdec.push(llr[i:i + push]) for i in range(0, n, push)]
parts.append(sdec.flush())
out2 = np.concatenate(parts)[:n]
dt = time.perf_counter() - t0
print(f"streamed decode over {mesh.devices.size} device(s), "
      f"chunk={sdec.chunk_frames} frames: {n/dt/1e6:.2f} Mb/s, "
      f"BER {float(ber(jnp.asarray(out2), bits)):.2e}")
assert np.array_equal(np.asarray(out), out2)         # bit-identical paths
