"""Quickstart: encode -> AWGN channel -> unified-kernel Viterbi decode.

PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FrameSpec, STD_K7, encode
from repro.core.pipeline import DecoderConfig, make_decoder
from repro.channel.sim import awgn, ber, bpsk

n = 20_000
rng = np.random.default_rng(0)
bits = jnp.asarray(rng.integers(0, 2, n))

# transmitter: standard (2,1,7) code, generators 171/133 (paper Fig. 1)
tx = bpsk(encode(bits, STD_K7).reshape(-1))

# channel: 3 dB Eb/N0
rx = awgn(jax.random.PRNGKey(1), tx, 3.0)

# receiver: the paper's unified kernel (forward + parallel traceback in one
# Pallas kernel, survivor paths in VMEM only), interpret=True on CPU
cfg = DecoderConfig(spec=FrameSpec(f=256, v1=20, v2=45, f0=32, v2s=45),
                    backend="kernel")
decode = make_decoder(cfg)
out = decode(rx.reshape(n, 2), n)

print(f"decoded {n} bits, BER = {float(ber(out, bits)):.2e} @ 3 dB "
      f"(theory ~1e-3)")
