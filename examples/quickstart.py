"""Quickstart: encode -> AWGN channel -> unified-kernel Viterbi decode.

PYTHONPATH=src python examples/quickstart.py

DecoderConfig knobs beyond the defaults shown here:
  * layout='sublane'     — Mosaic-native survivor layout (frames on the
    128 TPU lanes, flat stage-major scratches): bit-identical, and the
    form whose 32x survivor packing survives compiled-mode lane padding.
  * bm_dtype='bfloat16'  — store the eq.-9 branch metrics compressed
    (fp32 path-metric accumulation). Halves the second-largest VMEM term;
    BER within 1e-3 of float32 at Eb/N0 >= 2 dB (tests/test_ber.py).
  * frames_per_tile='auto' (default) budgets whichever kernel/layout/
    dtype combination actually runs (kernels/autotune.plan_tiles).

For unbounded inputs, use the STREAMING front-end instead of one shot:

    from repro.core import make_stream_decoder
    sdec = make_stream_decoder(cfg)           # chunk size from plan_decode
    bits_so_far = sdec.push(llr_chunk)        # async, double-buffered
    ...                                       # push as samples arrive
    tail = sdec.flush()                       # zero-padded tail + drain

Chunked output is bit-identical to the single-shot decode; pass ``mesh=``
(distributed.stream.frame_mesh()) to tile each chunk's frames across
devices.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FrameSpec, STD_K7, encode
from repro.core.pipeline import DecoderConfig, make_decoder
from repro.channel.sim import awgn, ber, bpsk

n = 20_000
rng = np.random.default_rng(0)
bits = jnp.asarray(rng.integers(0, 2, n))

# transmitter: standard (2,1,7) code, generators 171/133 (paper Fig. 1)
tx = bpsk(encode(bits, STD_K7).reshape(-1))

# channel: 3 dB Eb/N0
rx = awgn(jax.random.PRNGKey(1), tx, 3.0)

# receiver: the paper's unified kernel (forward + parallel traceback in one
# Pallas kernel, survivor paths in VMEM only), interpret=True on CPU
cfg = DecoderConfig(spec=FrameSpec(f=256, v1=20, v2=45, f0=32, v2s=45),
                    backend="kernel")
decode = make_decoder(cfg)
out = decode(rx.reshape(n, 2), n)

print(f"decoded {n} bits, BER = {float(ber(out, bits)):.2e} @ 3 dB "
      f"(theory ~1e-3)")
