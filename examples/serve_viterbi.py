"""Multi-tenant Viterbi decode service demo (repro.serve.DecodeServer).

Opens N sessions across three code configs — the standard K=7 rate-1/2
code, the same code punctured to rate 3/4 (raw punctured pushes, the
server depunctures in-stream), and a K=5 code — streams noisy symbols
chunk by chunk with the slot-based batching server, verifies every
session against its single-stream ``stream_decode`` baseline, and prints
the per-bucket occupancy/latency metrics plus the compiled-plan cache
stats (one trace per bucket shape, regardless of tenant churn).

  PYTHONPATH=src python examples/serve_viterbi.py --sessions 8 --chunks 6

(For the unrelated LM continuous-batching demo, see examples/serve_lm.py.)
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import DecoderConfig, FrameSpec, encode
from repro.core.puncture import puncture
from repro.core.stream import stream_decode
from repro.core.trellis import make_trellis
from repro.channel.sim import awgn, bpsk
from repro.serve import Backpressure, DecodeServer, PlanCache


def make_rx(trellis, n, rate, seed, snr=4.0):
    rng = np.random.default_rng(seed)
    bits = jnp.asarray(rng.integers(0, 2, n))
    coded = encode(bits, trellis)
    tx = bpsk(puncture(coded, rate)) if rate != "1/2" \
        else bpsk(coded.reshape(-1))
    rx = np.asarray(awgn(jax.random.PRNGKey(seed), tx, snr))
    return rx if rate != "1/2" else rx.reshape(n, 2)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--sessions", type=int, default=8)
    ap.add_argument("--chunks", type=int, default=6, help="chunks/session")
    ap.add_argument("--chunk-frames", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args(argv)

    k5 = make_trellis(5, (0o23, 0o35))
    spec12 = FrameSpec(f=64, v1=16, v2=20, f0=16, v2s=20)
    spec34 = FrameSpec(f=63, v1=21, v2=21, f0=21, v2s=21)
    cfgs = [("K7 r1/2", DecoderConfig(spec=spec12)),
            ("K7 r3/4", DecoderConfig(spec=spec34, rate="3/4")),
            ("K5 r1/2", DecoderConfig(trellis=k5, spec=spec12))]

    cache = PlanCache()
    srv = DecodeServer(slots=args.slots, max_sessions=args.sessions,
                       queue_depth=4, cache=cache)
    tenants = []
    for i in range(args.sessions):
        name, cfg = cfgs[i % len(cfgs)]
        n = args.chunks * args.chunk_frames * cfg.spec.f
        rx = make_rx(cfg.trellis, n, cfg.rate, seed=i)
        sid = srv.open_session(cfg, chunk_frames=args.chunk_frames)
        per = rx.shape[0] // args.chunks
        tenants.append(dict(sid=sid, name=name, cfg=cfg, rx=rx, n=n,
                            chunks=[rx[j * per:(j + 1) * per]
                                    for j in range(args.chunks)], out=[]))
    print(f"{args.sessions} sessions / {len(srv.buckets())} buckets, "
          f"chunk={args.chunk_frames} frames, slots={args.slots}")

    t0 = time.perf_counter()
    for r in range(args.chunks):
        for t in tenants:
            try:
                srv.push(t["sid"], t["chunks"][r])
            except Backpressure:
                srv.step()
                srv.push(t["sid"], t["chunks"][r])
        while srv.step():
            pass
        for t in tenants:
            t["out"].append(srv.poll(t["sid"]))
    for t in tenants:
        t["out"].append(srv.close_session(t["sid"]))
    dt = time.perf_counter() - t0

    total = 0
    for t in tenants:
        got = np.concatenate(t["out"])[:t["n"]]
        want = stream_decode(t["cfg"], t["rx"], t["n"],
                             chunk_frames=args.chunk_frames)
        assert np.array_equal(got, want), f"{t['name']} sid={t['sid']}"
        total += t["n"]
    print(f"decoded {total} bits in {dt*1e3:.0f} ms "
          f"({total/dt/1e6:.2f} Mb/s aggregate) — every session "
          f"bit-identical to its solo stream_decode")

    snap = srv.metrics_snapshot()
    print(f"{'bucket':<28}{'launches':>9}{'windows':>9}{'occup':>7}"
          f"{'p50 ms':>8}{'p99 ms':>8}")
    for row in snap["buckets"]:
        print(f"{row['bucket']:<28}{row['launches']:>9}{row['windows']:>9}"
              f"{row['occupancy']:>7.2f}{row['p50_ms']:>8.1f}"
              f"{row['p99_ms']:>8.1f}")
    print("plan cache:", snap["plan_cache"])


if __name__ == "__main__":
    main()
