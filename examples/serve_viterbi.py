"""Multi-tenant Viterbi decode service demo (repro.serve.DecodeServer).

Opens N sessions across three code configs — the standard K=7 rate-1/2
code, the same code punctured to rate 3/4 (raw punctured pushes, the
server depunctures in-stream), and a K=5 code — streams noisy symbols
chunk by chunk with the slot-based batching server, verifies every
session against its single-stream ``stream_decode`` baseline, and prints
the per-bucket occupancy/latency metrics plus the compiled-plan cache
stats (one trace per bucket shape, regardless of tenant churn).

  PYTHONPATH=src python examples/serve_viterbi.py --sessions 8 --chunks 6

``--chaos`` reruns the same workload under a seeded fault schedule
(repro.testing.faults): injected kernel-launch failures, slow launches
tripping the per-launch deadline, forced plan-cache evictions, and one
tenant pushing NaN-poisoned LLRs until it is quarantined. Healthy
sessions must still verify bit-identical; the demo prints the per-bucket
health and fault counters the server recovered through.

``--trace-out trace.json`` records the whole run with the obs tracer and
writes a Chrome trace-event file — open it in https://ui.perfetto.dev to
see the nested push/launch/retire spans (and, under ``--chaos``, the
retry/degrade recovery sub-spans) on a timeline. ``--metrics-out PREFIX``
writes the final ``metrics_snapshot()`` twice — ``PREFIX.prom``
(Prometheus text exposition, including the stage-latency histogram
series) and ``PREFIX.json`` — so one demo run leaves the complete
observability artifact set (trace + scrape + snapshot).

Durability (PR 8):

  ``--checkpoint-dir DIR`` snapshots the whole server to DIR/serve.ckpt
  after every round (atomic, CRC-validated). ``--kill-at-step N``
  injects a process 'death' at server step N and then demonstrates crash
  recovery live: the client restores a FRESH server from the last
  checkpoint, rewinds its own stream positions to the matching marker,
  and replays — every session still verifies bit-identical at the end.
  ``--resume`` restores server state (cumulative metrics/uptime, any
  carried-over sessions) from DIR/serve.ckpt at startup instead of
  building a fresh server.

Block-parallel decode (PR 9):

  ``--block-frames B`` (or ``auto``) switches the demo to a single
  long-frame (f=2048) tenant config decoded with intra-frame
  block-parallel mode — each frame is split into B overlapped blocks so
  one frame fills a tile the way many short frames do. ``--overlap``
  overrides the per-block warm-up/truncation depth (default ~5
  constraint lengths). The per-window launch latency (from the existing
  stage histograms) is printed either way, so the latency win is visible
  by rerunning with ``--block-frames 1``:

  PYTHONPATH=src python examples/serve_viterbi.py --sessions 2 \\
      --chunks 2 --chunk-frames 2 --block-frames auto

(For the unrelated LM continuous-batching demo, see examples/serve_lm.py.)
"""
import argparse
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import DecoderConfig, FrameSpec, encode
from repro.core.puncture import puncture
from repro.core.stream import stream_decode
from repro.core.trellis import make_trellis
from repro.channel.sim import awgn, bpsk
from repro.obs import Tracer, set_tracer, write_chrome_trace
from repro.serve import (Backpressure, DecodeServer, PlanCache,
                         SessionQuarantined)


def make_rx(trellis, n, rate, seed, snr=4.0):
    rng = np.random.default_rng(seed)
    bits = jnp.asarray(rng.integers(0, 2, n))
    coded = encode(bits, trellis)
    tx = bpsk(puncture(coded, rate)) if rate != "1/2" \
        else bpsk(coded.reshape(-1))
    rx = np.asarray(awgn(jax.random.PRNGKey(seed), tx, snr))
    return rx if rate != "1/2" else rx.reshape(n, 2)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--sessions", type=int, default=8)
    ap.add_argument("--chunks", type=int, default=6, help="chunks/session")
    ap.add_argument("--chunk-frames", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--chaos", action="store_true",
                    help="run under a seeded fault-injection schedule")
    ap.add_argument("--checkpoint-dir", metavar="DIR",
                    help="snapshot the server to DIR/serve.ckpt after "
                         "every round")
    ap.add_argument("--resume", action="store_true",
                    help="restore the server from the checkpoint dir at "
                         "startup (cumulative metrics carry over)")
    ap.add_argument("--kill-at-step", type=int, default=0, metavar="N",
                    help="inject a crash at server step N, then recover "
                         "from the last checkpoint and replay")
    ap.add_argument("--trace-out", metavar="PATH",
                    help="write a Chrome trace-event JSON of the run "
                         "(open in Perfetto / chrome://tracing)")
    ap.add_argument("--metrics-out", metavar="PREFIX",
                    help="write the final metrics_snapshot as PREFIX.prom "
                         "(Prometheus text exposition, incl. the stage "
                         "histograms) and PREFIX.json — with --trace-out "
                         "this leaves the complete observability artifact "
                         "set of a run")
    ap.add_argument("--block-frames", default=None, metavar="B|auto",
                    help="intra-frame block-parallel decode: split each "
                         "frame into B overlapped blocks ('auto' lets the "
                         "planner pick); any value switches the demo to a "
                         "long-frame (f=2048) workload, so '1' is the "
                         "sequential baseline of the same workload")
    ap.add_argument("--overlap", type=int, default=None, metavar="OV",
                    help="per-block warm-up/truncation overlap in trellis "
                         "stages (default: policy, ~5 constraint lengths)")
    args = ap.parse_args(argv)
    blk = args.block_frames
    if blk is not None and blk != "auto":
        blk = int(blk)
    if args.kill_at_step and not args.checkpoint_dir:
        args.checkpoint_dir = tempfile.mkdtemp(prefix="serve_ckpt_")

    tracer = None
    if args.trace_out:
        tracer = Tracer()
        set_tracer(tracer)          # lights up serve + stream + planner

    k5 = make_trellis(5, (0o23, 0o35))
    spec12 = FrameSpec(f=64, v1=16, v2=20, f0=16, v2s=20)
    spec34 = FrameSpec(f=63, v1=21, v2=21, f0=21, v2s=21)
    cfgs = [("K7 r1/2", DecoderConfig(spec=spec12)),
            ("K7 r3/4", DecoderConfig(spec=spec34, rate="3/4")),
            ("K5 r1/2", DecoderConfig(trellis=k5, spec=spec12))]
    if blk is not None:
        # short frames never block (policy threshold) — the latency win
        # is the point, so block mode runs one long-frame tenant config;
        # --block-frames 1 is the sequential baseline of that workload
        spec_long = FrameSpec(f=2048, v1=32, v2=32, f0=32, v2s=32)
        cfgs = [("K7 long", DecoderConfig(spec=spec_long, block_frames=blk,
                                          overlap=args.overlap))]

    from repro.testing import FaultInjector, FaultSpec
    from repro.testing.faults import InjectedCrash
    specs = []
    if args.chaos:
        # the LAST session is the poisoned tenant (sids count from 0)
        specs += [FaultSpec("launch_error", every=5),
                  FaultSpec("launch_slow", every=7, delay_s=0.05),
                  FaultSpec("plan_cache_miss", every=6),
                  FaultSpec("corrupt_llr", every=2, mode="nan",
                            sessions=(args.sessions - 1,))]
    if args.kill_at_step:
        specs.append(FaultSpec("crash_at_step", after=args.kill_at_step,
                               count=1))
    faults = FaultInjector(*specs, seed=3) if specs else None
    cache = PlanCache()
    ck_path = None
    if args.checkpoint_dir:
        os.makedirs(args.checkpoint_dir, exist_ok=True)
        ck_path = os.path.join(args.checkpoint_dir, "serve.ckpt")
    if args.resume and ck_path and os.path.exists(ck_path):
        srv = DecodeServer.restore(ck_path, cache=cache, faults=faults)
        for sid in list(srv._sessions):
            tail = srv.close_session(sid)
            print(f"resumed: closed carried-over session {sid} "
                  f"({len(tail)} undelivered bits recovered)")
        print(f"resumed from {ck_path}: cumulative uptime "
              f"{srv.metrics_snapshot()['totals']['uptime_s']:.2f}s, "
              f"restore #{srv.checkpoint_restores}")
    else:
        srv = DecodeServer(slots=args.slots, max_sessions=args.sessions,
                           queue_depth=4, cache=cache, faults=faults,
                           launch_timeout_s=0.03 if args.chaos else None,
                           max_retries=1, backoff_s=0.0,
                           quarantine_after=2)
    tenants = []
    for i in range(args.sessions):
        name, cfg = cfgs[i % len(cfgs)]
        n = args.chunks * args.chunk_frames * cfg.spec.f
        rx = make_rx(cfg.trellis, n, cfg.rate, seed=i)
        sid = srv.open_session(cfg, chunk_frames=args.chunk_frames)
        per = rx.shape[0] // args.chunks
        tenants.append(dict(sid=sid, name=name, cfg=cfg, rx=rx, n=n,
                            chunks=[rx[j * per:(j + 1) * per]
                                    for j in range(args.chunks)], out=[],
                            quarantined=None))
    print(f"{args.sessions} sessions / {len(srv.buckets())} buckets, "
          f"chunk={args.chunk_frames} frames, slots={args.slots}"
          + (", CHAOS schedule on" if args.chaos else ""))

    # client-side recovery marker: (next round, bits delivered per tenant,
    # quarantine states) as of the last checkpoint — on a crash the client
    # rewinds to it and replays against the restored server
    mark = None
    if ck_path:
        srv.checkpoint(ck_path)
        mark = (0, [0] * len(tenants), [None] * len(tenants))
    r = 0
    while r < args.chunks:
        try:
            for t in tenants:
                if t["quarantined"] is not None:
                    continue
                try:
                    srv.push(t["sid"], t["chunks"][r])
                except Backpressure as e:
                    # the structured hint says how many steps clear it
                    for _ in range(e.retry_after_steps or 1):
                        srv.step()
                    srv.push(t["sid"], t["chunks"][r])
                except SessionQuarantined as e:
                    t["quarantined"] = e
            while srv.step():
                pass
            for t in tenants:
                if t["quarantined"] is None:
                    try:
                        t["out"].append(srv.poll(t["sid"]))
                    except SessionQuarantined as e:
                        t["quarantined"] = e
            r += 1
            if ck_path:
                srv.checkpoint(ck_path)
                mark = (r, [sum(len(o) for o in t["out"]) for t in tenants],
                        [t["quarantined"] for t in tenants])
        except InjectedCrash as e:
            print(f"\nCRASH: {e} — restoring a fresh server from {ck_path}")
            srv = DecodeServer.restore(ck_path, cache=cache, faults=faults)
            r, delivered, quar = mark
            for t, nb, q in zip(tenants, delivered, quar):
                acc = (np.concatenate(t["out"]) if t["out"]
                       else np.zeros(0, np.int32))
                t["out"] = [acc[:nb]]
                t["quarantined"] = q
            print(f"restored (restore #{srv.checkpoint_restores}); "
                  f"replaying from round {r}")
    for t in tenants:
        t["out"].append(srv.close_session(t["sid"]))  # quarantined too

    total = 0
    poisoned_sids = set(faults._specs["corrupt_llr"][0].sessions) \
        if args.chaos else set()
    for t in tenants:
        if t["sid"] in poisoned_sids:
            continue                      # its input WAS corrupted
        got = np.concatenate(t["out"])[:t["n"]]
        want = stream_decode(t["cfg"], t["rx"], t["n"],
                             chunk_frames=args.chunk_frames)
        assert np.array_equal(got, want), f"{t['name']} sid={t['sid']}"
        total += t["n"]

    snap = srv.metrics_snapshot()
    tot = snap["totals"]
    # throughput/uptime come from the metrics themselves now — no more
    # hand-timed loop around the workload
    print(f"decoded {total} verified bits in {tot['uptime_s']*1e3:.0f} ms "
          f"({tot['mbps']:.2f} Mb/s aggregate) — every healthy session "
          f"bit-identical to its solo stream_decode")
    for t in tenants:
        if t["quarantined"] is not None:
            e = t["quarantined"]
            print(f"quarantined: {t['name']} sid={e.sid} after "
                  f"{e.strikes} poisoned pushes ({e.reason})")

    print(f"{'bucket':<28}{'launches':>9}{'windows':>9}{'occup':>7}"
          f"{'p50 ms':>8}{'p99 ms':>8}{'Mb/s':>7}  {'health':<9}")
    for row in snap["buckets"]:
        print(f"{row['bucket']:<28}{row['launches']:>9}{row['windows']:>9}"
              f"{row['occupancy']:>7.2f}{row['p50_ms']:>8.1f}"
              f"{row['p99_ms']:>8.1f}{row['mbps']:>7.2f}  "
              f"{row['health']:<9}")
    print(f"{'stage':<16}{'count':>7}{'p50 ms':>8}{'p99 ms':>8}"
          f"{'max ms':>8}")
    for stage, s in sorted(snap["stages"].items()):
        print(f"{stage:<16}{s['count']:>7}{s['p50']:>8.2f}{s['p99']:>8.2f}"
              f"{s['max']:>8.2f}")
    la = snap["stages"].get("launch_ms")
    if la and la.get("count"):
        blocked = blk not in (None, 1)
        mode = (f"block-parallel ({args.block_frames} blocks/frame)"
                if blocked else "sequential scan")
        hint = (" — rerun with --block-frames 1 to compare" if blocked
                else " — rerun with --block-frames auto for the blocked "
                     "plan")
        print(f"per-window launch latency [{mode}]: p50 {la['p50']:.2f} ms, "
              f"p99 {la['p99']:.2f} ms over {la['count']} launches{hint}")
    print("plan cache:", snap["plan_cache"])
    if ck_path:
        print(f"checkpoints: {snap['checkpoint']['saves']} saved, "
              f"{snap['checkpoint']['restores']} restores -> {ck_path}")
    if args.chaos:
        print(f"faults recovered: {tot['launch_errors']} launch errors, "
              f"{tot['timeouts']} timeouts, {tot['retries']} retries, "
              f"{tot['degraded']} degraded launches, "
              f"{tot['cache_refreshes']} cache refreshes, "
              f"{tot['sanitized_values']} LLRs sanitized, "
              f"{tot['quarantined']} quarantined — overall "
              f"health={tot['health']}")
        print("injector:", snap["faults"])
    if args.metrics_out:
        from repro.obs import prometheus_text, write_metrics_json
        prom_path = args.metrics_out + ".prom"
        json_path = args.metrics_out + ".json"
        with open(prom_path, "w") as fh:
            fh.write(prometheus_text(snap))
        write_metrics_json(snap, json_path)
        print(f"metrics: exposition -> {prom_path}, snapshot -> "
              f"{json_path}")
    if tracer is not None:
        obj = write_chrome_trace(tracer, args.trace_out)
        set_tracer(None)
        print(f"trace: {len(obj['traceEvents'])} events -> "
              f"{args.trace_out} (open in https://ui.perfetto.dev)")


if __name__ == "__main__":
    main()
