"""End-to-end driver: train a ~100M-param qwen3-family model for a few
hundred steps on the synthetic pipeline with checkpointing + watchdog.

PYTHONPATH=src python examples/train_lm.py [--steps 300]
(Use --tiny for a quick smoke run.)
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.launch.train import main as train_main


def build_100m():
    # ~100M-param member of the qwen3 family
    base = get_config("qwen3_32b", reduced=True)
    return dataclasses.replace(
        base, name="qwen3-100m", num_layers=8, d_model=640, num_heads=10,
        num_kv_heads=2, d_ff=1792, vocab=32000, head_dim=64,
        vocab_round=128)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    args = ap.parse_args()

    if args.tiny:
        train_main(["--arch", "qwen3_32b", "--reduced",
                    "--steps", str(min(args.steps, 30)),
                    "--global-batch", "4", "--seq", "32"])
    else:
        # register the 100M config by monkey-free direct use of the driver
        # internals (the driver accepts any ModelConfig via get_config; for
        # the example we inline the equivalent loop)
        import repro.launch.train as TR
        import jax.numpy as jnp
        from repro.data import DataConfig, SyntheticLM
        from repro.models import build_model
        from repro.optim import adamw, warmup_cosine
        from repro.train import LoopConfig, make_train_step, train_loop

        cfg = build_100m()
        bundle = build_model(cfg)
        nparams = sum(x.size for x in jax.tree.leaves(
            jax.eval_shape(bundle.init, jax.random.PRNGKey(0))))
        print(f"{cfg.name}: {nparams/1e6:.1f}M params")
        opt = adamw(warmup_cosine(3e-4, 20, args.steps))
        params = bundle.init(jax.random.PRNGKey(0))
        state = {"params": params, "opt": opt.init(params)}
        step = jax.jit(make_train_step(bundle, opt), donate_argnums=(0, 1))
        data = SyntheticLM(cfg, DataConfig(8, 256, mode="learnable"))
        lc = LoopConfig(total_steps=args.steps, ckpt_dir="/tmp/ckpt_100m",
                        ckpt_every=100)
        stats = train_loop(
            lambda p, o, b: step(p, o, {k: jnp.asarray(v)
                                        for k, v in b.items()}),
            state, data, lc)
        print(f"final loss: {stats.last_loss:.4f} after {stats.steps_run} steps")
