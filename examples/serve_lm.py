"""Batched serving example (continuous batching, slot-based).

PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main(["--arch", "qwen3_32b", "--requests", "6", "--slots", "4",
          "--gen", "12"])
