"""Batched LM serving example (continuous batching, slot-based).

NOTE: this is the LANGUAGE-MODEL scaffolding demo (repro.launch.serve,
token-by-token decode of transformer requests). The Viterbi decode
service — the multi-tenant session server this repo's paper work feeds —
is ``repro.serve`` / examples/serve_viterbi.py.

PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main(["--arch", "qwen3_32b", "--requests", "6", "--slots", "4",
          "--gen", "12"])
